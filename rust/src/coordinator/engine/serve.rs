//! Sharded serving: distribute the posterior prediction path across
//! ranks.
//!
//! Training parallelises the *fit*; this module parallelises the
//! *serve*. The precomputed posterior state
//! ([`PosteriorCore`]: `A⁻¹P`, the Woodbury matrix, kernel, Z) is
//! broadcast through `Comm::bcast` **once per session**, then each
//! prediction batch is partitioned over ranks with the same
//! [`Partition`] machinery training uses for datapoints:
//!
//! ```text
//!   L:  bcast [PREDICT, Nt] ── send shard rows ──▸ compute own shard ── gather
//!   W:  bcast ───────────────▸ recv shard ───────▸ predict_batch ────── gather
//! ```
//!
//! Per-shard evaluation goes through [`Backend::predict_batch`] (serial
//! scalar rows on `rust-cpu`, intra-rank row-block fan-out on
//! `parallel-cpu`, host fallback on `xla`), and the per-rank results are
//! gathered back to the leader in rank order. Prediction rows are
//! independent — there is **no cross-row reduction** — so the assembled
//! output is bit-identical to the single-node
//! [`Posterior`](crate::models::Posterior) built from the same core, at
//! every cluster size (asserted for ranks 1–9 in
//! `rust/tests/serve_test.rs`).
//!
//! **Batch streams.** The per-batch protocol above is lock-step: the
//! leader only announces batch k+1 after batch k's gather has fully
//! drained, so workers idle for a whole leader round-trip between
//! batches. [`DistributedPosterior::predict_stream`] reorders the
//! protocol — nothing else — so at most **two batches are in flight**:
//!
//! ```text
//!   L:  issue(k) ─ issue(k+1) ─ own(k) ─ gather(k) ─ issue(k+2) ─ own(k+1) ─ …
//!   W:  recv cmd(k) ─ recv shard(k) ─ prefetch cmd+shard(k+1) ─┐
//!                                      compute(k) ─ gather(k) ─┴─▸ compute(k+1) ─ …
//! ```
//!
//! `issue` = sub-command broadcast + shard sends (both non-blocking on
//! this transport), so batch k+1's rows are already parked in a worker's
//! mailbox while it computes batch k: the command wire carries a
//! *stream flag* telling the worker the next announcement is in flight,
//! and the worker pulls it (plus its shard) into a back buffer (the
//! serve scratch's pending pair) **before** computing the current
//! batch. Per-batch compute and rank-order assembly are the exact same
//! code as the sequential path, so streamed output is **bit-identical**
//! to `predict_into` batch for batch. Fail-flag, poison and hot-swap
//! semantics survive mid-stream: a failed batch is completed (lockstep
//! preserved, first error returned, the session stays usable), and a
//! swap broadcast that lands between two streamed announcements is
//! applied after the earlier batch and before the later one — broadcast
//! order.
//!
//! Failure protocol: a rank whose shard computation errors ships a
//! one-element `[1.0]` failure payload instead of its results, so the
//! gather stays in lockstep and the leader surfaces the failure as an
//! `Err` without desyncing the session.
//!
//! Steady-state allocation: the leader caches the row partition per
//! batch size and reuses wire/output scratch buffers
//! (`CycleScratch`-style), so serving a stream of same-sized batches
//! does not allocate beyond the transport's own message copies.
//!
//! Mid-session the leader can **hot-swap** the posterior: a `SRV_SWAP`
//! broadcast carries a replacement core and every subsequent batch is
//! evaluated against it on every rank (no teardown, no re-partition).
//! From a training cluster the swap composes with the engine's
//! stats-only pass: `SRV_REFIT` sends the workers into one distributed
//! STATS round, the leader rebuilds the core from the reduced
//! statistics, and the swap broadcast follows
//! ([`DistributedEvaluator::refit_and_swap`](super::cycle::DistributedEvaluator::refit_and_swap)).
//! A failed refit is atomic: no swap broadcast goes out and the session
//! keeps serving the old posterior.
//!
//! Two ways in:
//! - standalone, over a raw [`Comm`] (see `examples/scaling_demo.rs`):
//!   [`DistributedPosterior::leader`] / [`worker_serve`] (plus
//!   [`DistributedPosterior::rebroadcast`] for leader-built swaps);
//! - from a training cluster, via
//!   [`DistributedEvaluator::begin_serving`](super::cycle::DistributedEvaluator::begin_serving) —
//!   a fitted model is served by the same ranks without leaving the
//!   SPMD world.

use crate::collectives::Comm;
// The shard tag and the serve sub-command verbs live in the
// cluster-wide registry (`collectives::protocol`), where uniqueness
// across subsystems is asserted. A `SRV_PREDICT` wire is
// `[SRV_PREDICT, nt]` or `[SRV_PREDICT, nt, stream]`, where a `stream`
// flag of 1.0 announces that the *next* sub-command broadcast (and its
// shard sends) are already in flight — the worker may prefetch them
// before computing this batch.
use crate::collectives::protocol::{SRV_DONE, SRV_PREDICT, SRV_REFIT, SRV_SWAP, TAG_XSTAR};
use crate::coordinator::backend::Backend;
use crate::coordinator::partition::Partition;
use crate::linalg::Mat;
use crate::math::predict::PosteriorCore;
use anyhow::{anyhow, Result};

/// Sanity cap on a `SRV_PREDICT` row count. The value comes off a
/// collective wire as f64; a corrupt wire can carry NaN (`as usize`
/// saturates to 0 and the partition constructor asserts), a negative, or
/// something huge (the per-batch partition build allocates one chunk
/// entry per `rows_per_chunk` rows, so an absurd count is an OOM before
/// it is anything else). Matches `MAX_WIRE_DIM` in `math::predict` — far
/// above any servable batch, small enough that the worst-case partition
/// allocation stays bounded. Anything past it is corruption, not a batch.
const MAX_BATCH_ROWS: f64 = 16_777_216.0; // 2^24

/// How many recent row partitions a session caches (LRU). Streamed
/// serving holds two batches in flight (plus the one being issued), so a
/// single slot would thrash on mixed-size streams — and the serving
/// front-end's micro-batcher emits *ragged* sizes (whatever mix of
/// client requests a deadline closed over), so the window must be wide
/// enough that a steady traffic mix of a dozen-odd distinct batch sizes
/// stays resident instead of rebuilding a partition per batch.
const PARTITION_CACHE: usize = 16;

/// Parse a serve sub-command wire as a `SRV_PREDICT` announcement:
/// `Ok(Some((nt, stream)))` for a well-formed batch, `Ok(None)` when the
/// verb is not `SRV_PREDICT` at all, `Err` for a `SRV_PREDICT` wire too
/// short/long to carry its fields or whose row count is not a valid
/// batch size. Both the worker's main dispatch and its streamed prefetch
/// go through here, so the validation cannot drift between them.
fn parse_predict(cmd: &[f64]) -> Result<Option<(usize, bool)>> {
    if cmd.first() != Some(&SRV_PREDICT) {
        return Ok(None);
    }
    if cmd.len() < 2 || cmd.len() > 3 {
        return Err(anyhow!("SRV_PREDICT wire has {} element(s)", cmd.len()));
    }
    let ntf = cmd[1];
    if !ntf.is_finite() || ntf < 1.0 || ntf.fract() != 0.0 || ntf > MAX_BATCH_ROWS {
        return Err(anyhow!("SRV_PREDICT row count {ntf} is not a valid batch size"));
    }
    let stream = match cmd.get(2) {
        None => false,
        Some(&v) if v == 0.0 => false,
        Some(&v) if v == 1.0 => true,
        Some(&v) => return Err(anyhow!("SRV_PREDICT stream flag {v} is neither 0 nor 1")),
    };
    Ok(Some((ntf as usize, stream)))
}

/// What ended a [`DistributedPosterior::serve_until`] stint.
#[derive(Debug, PartialEq, Eq)]
pub enum ServeSignal {
    /// The leader closed the session.
    Done,
    /// The leader requested a refit round: the caller must run the
    /// worker half of the stats collective, then call `serve_until`
    /// again (a successful refit is followed by a swap broadcast, which
    /// `serve_until` handles internally).
    Refit,
}

/// Reusable per-session buffers so the steady-state serve loop stops
/// allocating: command/shard wires, the worker's shard matrix, per-rank
/// mean/variance staging, the gather payload, and — in streamed mode —
/// the **back buffer** holding the next batch's prefetched command and
/// shard wire while the front buffers (`xshard`/`mean`/`var`) carry the
/// batch currently being computed.
#[derive(Default)]
struct ServeScratch {
    /// Sub-command broadcast buffer (round-trips through `bcast`).
    cmd: Vec<f64>,
    /// Leader-side per-rank shard wire (packed X* rows).
    xwire: Vec<f64>,
    /// Worker-side received shard (rows × Q).
    xshard: Mat,
    /// This rank's shard mean (rows × D, row-major).
    mean: Vec<f64>,
    /// This rank's shard variance (rows).
    var: Vec<f64>,
    /// Gather payload: `mean ++ var ++ [fail flag]`.
    payload: Vec<f64>,
    /// Streamed mode: the next sub-command wire, prefetched before the
    /// current batch's compute; handled at the top of the serve loop.
    pending_cmd: Option<Vec<f64>>,
    /// Streamed mode: the next batch's shard wire (the double buffer's
    /// back half — the current batch occupies `xshard`).
    pending_shard: Option<Vec<f64>>,
}

/// One rank's half of a sharded serving session. Build with
/// [`DistributedPosterior::leader`] on rank 0 and
/// [`DistributedPosterior::worker`] elsewhere (or let
/// [`worker_serve`] do both worker steps); the construction pair
/// performs the one-time posterior broadcast.
pub struct DistributedPosterior {
    core: PosteriorCore,
    /// Rows per partition chunk (the serving analog of the training
    /// chunk size; granularity of the per-rank row split).
    rows_per_chunk: usize,
    /// Recently used row partitions, each keyed by the **(batch size,
    /// rank count)** pair it was built for (a posterior reused against a
    /// different-sized communicator must not reuse the old row split).
    /// True LRU: front entry is the most recent, a hit moves its entry
    /// back to the front, the back entry is evicted at capacity
    /// [`PARTITION_CACHE`] — so a recurring mix of ragged batch sizes
    /// (the serving front-end's steady state) stays resident.
    parts: Vec<(usize, usize, Partition)>,
    /// How many partitions this session has **built** (cache misses).
    /// Cheap observability for the LRU: a steady stream of recurring
    /// batch sizes must keep this flat (see `partition_builds`).
    builds: u64,
    scratch: ServeScratch,
    /// First worker-side error of the session (reported when it closes).
    sticky: Option<anyhow::Error>,
    /// Set when a swap broadcast failed to unpack: the rank no longer
    /// holds the posterior the leader believes it does, so every
    /// subsequent batch is fail-flagged (never silently served stale)
    /// while the collectives stay in lockstep. A later good swap clears
    /// it.
    poisoned: bool,
}

impl DistributedPosterior {
    /// Leader (rank 0): broadcast `core` (and the partition granularity)
    /// to every rank, opening the serving session. `Err` is a terminal
    /// transport failure (a dead peer).
    pub fn leader(core: PosteriorCore, rows_per_chunk: usize, comm: &mut Comm)
                  -> Result<DistributedPosterior> {
        assert!(rows_per_chunk > 0, "rows_per_chunk must be positive");
        let mut wire = Vec::with_capacity(
            1 + PosteriorCore::wire_len(core.q(), core.m(), core.d()));
        wire.push(rows_per_chunk as f64);
        core.pack_into(&mut wire);
        comm.bcast(0, wire)?;
        Ok(DistributedPosterior { core, rows_per_chunk, parts: Vec::new(), builds: 0,
                                  scratch: ServeScratch::default(), sticky: None,
                                  poisoned: false })
    }

    /// Worker: receive the posterior broadcast that opens the session.
    ///
    /// A wire whose *core* fails to unpack does not eject the rank (the
    /// leader would desync into the first batch): the session opens
    /// **poisoned** — the partition granularity in the header is enough
    /// to mirror the leader's shard sends, every batch is fail-flagged,
    /// and the sticky error names the cause at close. Only a wire too
    /// broken to carry the granularity itself (empty, or zero
    /// rows-per-chunk — which the leader cannot produce) is a hard
    /// error, because without it the shard recvs cannot be mirrored.
    pub fn worker(comm: &mut Comm) -> Result<DistributedPosterior> {
        let wire = comm.bcast(0, Vec::new())?;
        if wire.is_empty() {
            return Err(anyhow!("empty posterior broadcast"));
        }
        let rows_per_chunk = wire[0] as usize;
        if rows_per_chunk == 0 {
            return Err(anyhow!("rows_per_chunk must be positive"));
        }
        let (core, sticky, poisoned) = match PosteriorCore::unpack(&wire[1..]) {
            Ok(core) => (core, None, false),
            Err(e) => {
                // placeholder core, never evaluated while poisoned
                let empty = PosteriorCore {
                    kern: crate::kern::RbfArd::new(1.0, Vec::new()),
                    z: Mat::zeros(0, 0),
                    beta: 1.0,
                    ainv_p: Mat::zeros(0, 0),
                    woodbury: Mat::zeros(0, 0),
                };
                (empty, Some(anyhow!("posterior broadcast: {e:#}")), true)
            }
        };
        Ok(DistributedPosterior { core, rows_per_chunk, parts: Vec::new(), builds: 0,
                                  scratch: ServeScratch::default(), sticky,
                                  poisoned })
    }

    /// The broadcast posterior state.
    pub fn core(&self) -> &PosteriorCore {
        &self.core
    }

    /// Look up (or build) the row partition for a batch of `nt` rows
    /// over `ranks` ranks and move it to the cache front. Keying on the
    /// full **(batch size, rank count)** pair matters: a posterior
    /// reused against a different-sized communicator must not reuse the
    /// old row split. The cache is a true LRU of [`PARTITION_CACHE`]
    /// entries: a hit moves the entry to the front, a miss evicts the
    /// *least recently used* (back) entry — so both the streamed
    /// protocol's in-flight window and the front-end batcher's recurring
    /// mix of ragged batch sizes stay resident.
    fn partition_for(&mut self, nt: usize, ranks: usize) -> &Partition {
        match self.parts.iter().position(|(n, r, _)| *n == nt && *r == ranks) {
            Some(i) => {
                // move-to-front keeps `parts` in recency order, which is
                // what makes the pop() below evict the LRU entry
                let hit = self.parts.remove(i);
                self.parts.insert(0, hit);
            }
            None => {
                if self.parts.len() == PARTITION_CACHE {
                    self.parts.pop();
                }
                self.parts.insert(
                    0, (nt, ranks, Partition::new(nt, self.rows_per_chunk, ranks)));
                self.builds += 1;
            }
        }
        &self.parts[0].2
    }

    /// How many row partitions this session has built (LRU cache
    /// misses). A steady stream of recurring batch sizes must keep this
    /// flat at the number of *distinct* sizes — if it grows with the
    /// batch count, the cache is thrashing (the regression the
    /// front-end's ragged micro-batches would otherwise reintroduce).
    pub fn partition_builds(&self) -> u64 {
        self.builds
    }

    /// Leader: predict one batch, sharded across ranks (allocating
    /// convenience wrapper around
    /// [`predict_into`](DistributedPosterior::predict_into)).
    pub fn predict(&mut self, comm: &mut Comm, backend: &mut dyn Backend,
                   xstar: &Mat) -> Result<(Mat, Vec<f64>)> {
        let mut mean = Mat::zeros(0, 0);
        let mut var = Vec::new();
        self.predict_into(comm, backend, xstar, &mut mean, &mut var)?;
        Ok((mean, var))
    }

    /// Leader: predict one batch, sharded across ranks, into reusable
    /// output buffers (resized only when the batch shape changes — the
    /// zero-allocation steady-state entry point).
    ///
    /// Row `i` of `xstar` produces row `i` of `mean_out` and
    /// `var_out[i]`; results are assembled in rank order, which is row
    /// order, so the output is bit-identical to the single-node
    /// posterior.
    pub fn predict_into(&mut self, comm: &mut Comm, backend: &mut dyn Backend,
                        xstar: &Mat, mean_out: &mut Mat, var_out: &mut Vec<f64>)
                        -> Result<()> {
        self.prepare_outputs(xstar, mean_out, var_out)?;
        if xstar.rows() == 0 {
            return Ok(()); // nothing to shard; no collective round needed
        }
        self.issue_batch(comm, xstar, false)?;
        self.complete_batch(comm, backend, xstar, mean_out, var_out)
    }

    /// Leader: serve a run of batches as a **stream** — batch k+1's
    /// sub-command broadcast and shard sends go out *before* batch k's
    /// gather is collected (at most two batches in flight, see the
    /// module doc), so workers roll from one batch's compute straight
    /// into the next instead of idling for the leader's round-trip.
    ///
    /// Per-batch compute and rank-order assembly are the same code as
    /// [`predict_into`](DistributedPosterior::predict_into), so the
    /// output is bit-identical to serving the batches sequentially. A
    /// failing batch does not tear the stream down: every issued batch
    /// is completed (the collectives stay in lockstep and the session
    /// stays usable) and the first error is returned.
    pub fn predict_stream(&mut self, comm: &mut Comm, backend: &mut dyn Backend,
                          batches: &[Mat]) -> Result<Vec<(Mat, Vec<f64>)>> {
        let mut outs: Vec<(Mat, Vec<f64>)> =
            batches.iter().map(|_| (Mat::zeros(0, 0), Vec::new())).collect();
        self.predict_stream_into(comm, backend, batches, &mut outs)?;
        Ok(outs)
    }

    /// [`predict_stream`](DistributedPosterior::predict_stream) into
    /// reusable output buffers, one `(mean, variance)` slot per batch —
    /// the steady-state entry point, like
    /// [`predict_into`](DistributedPosterior::predict_into) for the
    /// sequential path. Empty batches cost no collective round, exactly
    /// as in the sequential path.
    pub fn predict_stream_into(&mut self, comm: &mut Comm, backend: &mut dyn Backend,
                               batches: &[Mat], outs: &mut [(Mat, Vec<f64>)])
                               -> Result<()> {
        if batches.len() != outs.len() {
            return Err(anyhow!("{} batches but {} output slots",
                               batches.len(), outs.len()));
        }
        // validate and size every slot before any collective goes out,
        // so a malformed batch fails the call without touching the wire
        for (b, (mean, var)) in batches.iter().zip(outs.iter_mut()) {
            self.prepare_outputs(b, mean, var)?;
        }
        let next_live =
            |from: usize| (from..batches.len()).find(|&i| batches[i].rows() > 0);
        let Some(mut cur) = next_live(0) else {
            return Ok(()); // all batches empty: nothing to shard
        };
        let mut nxt = next_live(cur + 1);
        self.issue_batch(comm, &batches[cur], nxt.is_some())?;

        let mut first_err: Option<anyhow::Error> = None;
        loop {
            // issue batch k+1 before collecting batch k. An issue error
            // is a terminal transport failure (dead peer), unlike a
            // batch's compute error — no point completing the stream.
            let issued = nxt;
            if let Some(n) = issued {
                nxt = next_live(n + 1);
                self.issue_batch(comm, &batches[n], nxt.is_some())?;
            }
            let (mean, var) = &mut outs[cur];
            if let Err(e) = self.complete_batch(comm, backend, &batches[cur], mean, var) {
                if first_err.is_none() {
                    first_err = Some(anyhow!("stream batch {cur}: {e:#}"));
                }
            }
            match issued {
                Some(n) => cur = n,
                None => break,
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Validate a batch against the posterior and size the caller's
    /// output buffers (reallocated only when the batch shape changes).
    /// Crate-visible for the serving front-end's batcher, which drives
    /// the issue/complete halves directly.
    pub(crate) fn prepare_outputs(&self, xstar: &Mat, mean_out: &mut Mat,
                                  var_out: &mut Vec<f64>) -> Result<()> {
        let nt = xstar.rows();
        let d = self.core.d();
        if xstar.cols() != self.core.q() {
            return Err(anyhow!("xstar has Q={}, posterior expects Q={}",
                               xstar.cols(), self.core.q()));
        }
        if mean_out.rows() != nt || mean_out.cols() != d {
            *mean_out = Mat::zeros(nt, d);
        }
        var_out.resize(nt, 0.0);
        Ok(())
    }

    /// First half of one batch's leader protocol: broadcast the
    /// sub-command (`stream` marks a batch whose successor will be
    /// issued before this batch's gather) and ship each worker its
    /// contiguous run of rows. `xstar` must be non-empty. Sends are
    /// non-blocking, so this returns without waiting on any rank.
    ///
    /// Crate-visible for the serving front-end: its batcher keeps up to
    /// two coalesced batches in flight by pairing `issue_batch` /
    /// `complete_batch` directly, exactly as `predict_stream_into` does.
    /// Callers must pass `stream = true` **only** when the next batch's
    /// `issue_batch` follows immediately (before this batch's
    /// `complete_batch`): the flag makes the worker block on the next
    /// sub-command broadcast before computing this batch, so a flag with
    /// no follow-up broadcast deadlocks the cluster.
    // lint: no-alloc
    pub(crate) fn issue_batch(&mut self, comm: &mut Comm, xstar: &Mat, stream: bool)
                              -> Result<()> {
        let nt = xstar.rows();
        let ranks = comm.size();
        self.partition_for(nt, ranks);
        let scratch = &mut self.scratch;

        // announce the batch
        scratch.cmd.clear();
        scratch.cmd.extend_from_slice(&[SRV_PREDICT, nt as f64,
                                        if stream { 1.0 } else { 0.0 }]);
        scratch.cmd = comm.bcast(0, std::mem::take(&mut scratch.cmd))?;

        // ship each worker its contiguous run of rows
        let part = &self.parts[0].2;
        for r in 1..ranks {
            if let Some(sp) = part.worker_span(r) {
                scratch.xwire.clear();
                scratch.xwire.extend_from_slice(
                    &xstar.as_slice()[sp.start * xstar.cols()..sp.end * xstar.cols()]);
                comm.send(r, TAG_XSTAR, &scratch.xwire)?;
            }
        }
        Ok(())
    }

    /// Second half of one batch's leader protocol: compute rank 0's own
    /// shard straight into the output buffers (no staging copies),
    /// gather the fail-flagged worker payloads, and assemble them in
    /// rank order — which is row order. Crate-visible for the serving
    /// front-end (see [`issue_batch`](DistributedPosterior::issue_batch));
    /// a batch error leaves the session usable, exactly as in
    /// `predict_stream_into`.
    // lint: no-alloc
    pub(crate) fn complete_batch(&mut self, comm: &mut Comm, backend: &mut dyn Backend,
                                 xstar: &Mat, mean_out: &mut Mat,
                                 var_out: &mut Vec<f64>) -> Result<()> {
        let nt = xstar.rows();
        let d = self.core.d();
        let ranks = comm.size();
        // absorb worker payloads already in flight before the leader's
        // own compute, so they park during it instead of queueing behind
        // it (a drain moves messages, never sends, and preserves
        // per-(src, tag) order — the gather below is oblivious to it)
        comm.drain_pending();
        // leader's own shard (rank 0 always owns the first run of rows)
        let sp0 = self.partition_for(nt, ranks).worker_span(0)
            .ok_or_else(|| anyhow!("rank 0 owns no rows in a {nt}-row batch"))?;
        let rows0 = sp0.len();
        let own = backend.predict_batch(&self.core, xstar, sp0.start, rows0,
                                        &mut mean_out.as_mut_slice()
                                            [sp0.start * d..sp0.end * d],
                                        &mut var_out[sp0.start..sp0.end]);

        // gather (fail-flagged payloads keep the collective in lockstep
        // even when a rank's compute errored; the leader's own results
        // are already in place, so its payload is the flag alone)
        let scratch = &mut self.scratch;
        scratch.payload.clear();
        scratch.payload.push(if own.is_ok() { 0.0 } else { 1.0 });
        let gathered = comm.gather(0, &scratch.payload)?
            .ok_or_else(|| anyhow!("gather returned no data at the root"))?;
        own.map_err(|e| anyhow!("rank 0 prediction failed: {e:#}"))?;

        // assemble worker shards into the output rows
        let part = &self.parts[0].2;
        for (r, piece) in gathered.iter().enumerate().skip(1) {
            let Some(sp) = part.worker_span(r) else {
                continue; // chunkless rank contributed nothing
            };
            let rows = sp.len();
            let want = rows * (d + 1) + 1;
            if piece.len() != want || piece.last() != Some(&0.0) {
                return Err(anyhow!("prediction failed on rank {r}"));
            }
            mean_out.as_mut_slice()[sp.start * d..sp.end * d]
                .copy_from_slice(&piece[..rows * d]);
            var_out[sp.start..sp.end].copy_from_slice(&piece[rows * d..rows * (d + 1)]);
        }
        Ok(())
    }

    /// Worker: serve prediction batches until the leader ends the
    /// session. A failing shard computation is reported through the
    /// fail-flagged gather payload (the session keeps running); the
    /// first such error is returned once the leader closes the session.
    /// A refit request outside a training cluster is a protocol error —
    /// only [`serve_until`](DistributedPosterior::serve_until) callers
    /// (the evaluator's worker loop) can run the stats round it needs.
    pub fn serve(&mut self, comm: &mut Comm, backend: &mut dyn Backend) -> Result<()> {
        match self.serve_until(comm, backend)? {
            ServeSignal::Done => Ok(()),
            ServeSignal::Refit => Err(anyhow!(
                "refit requested outside a training cluster")),
        }
    }

    /// Worker: obey serve sub-commands until the leader closes the
    /// session ([`ServeSignal::Done`]) or requests a refit round
    /// ([`ServeSignal::Refit`] — training clusters only; the caller runs
    /// the worker half of the stats collective and re-enters). Posterior
    /// hot-swaps (`SRV_SWAP` broadcasts) are handled internally: the
    /// replacement core takes effect for every subsequent batch.
    // lint: no-alloc
    pub fn serve_until(&mut self, comm: &mut Comm, backend: &mut dyn Backend)
                       -> Result<ServeSignal> {
        let rank = comm.rank();
        let ranks = comm.size();

        loop {
            // streamed mode parks the next command here before the
            // previous batch's compute; otherwise read the broadcast
            let cmd = match self.scratch.pending_cmd.take() {
                Some(c) => c,
                // lint: allow(no-alloc-hot-path) — empty receive sentinel
                None => comm.bcast(0, Vec::new())?,
            };
            if cmd.is_empty() || cmd[0] == SRV_DONE {
                return match self.sticky.take() {
                    Some(e) => Err(anyhow!("rank {rank}: {e:#}")),
                    None => Ok(ServeSignal::Done),
                };
            }
            if cmd[0] == SRV_REFIT {
                return Ok(ServeSignal::Refit);
            }
            if cmd[0] == SRV_SWAP {
                // hot-swap: the rest of the broadcast is the new core. A
                // malformed swap wire must neither eject this rank from
                // the session (the leader would desync into the next
                // batch) nor let it silently serve the stale core — so
                // the session is poisoned: every subsequent batch is
                // fail-flagged until a good swap lands, and the sticky
                // error names the cause at close.
                match PosteriorCore::unpack(&cmd[1..]) {
                    Ok(core) => {
                        self.core = core;
                        self.poisoned = false;
                    }
                    Err(e) => {
                        self.poisoned = true;
                        if self.sticky.is_none() {
                            self.sticky = Some(anyhow!("posterior swap: {e:#}"));
                        }
                    }
                }
                continue;
            }
            let (nt, stream) = match parse_predict(&cmd) {
                Ok(Some(v)) => v,
                Ok(None) => {
                    // Unknown verb: guessing the leader's protocol state
                    // (the old code fell through to SRV_PREDICT and
                    // indexed cmd[1] — a panic on short wires, a
                    // mis-serve otherwise) is how one corrupt wire tears
                    // a cluster down. No wire an honest leader produces
                    // looks like this, so stay parked at the sub-command
                    // broadcast — lockstep by construction — and report
                    // through the sticky error at close.
                    if self.sticky.is_none() {
                        self.sticky = Some(anyhow!(
                            "unknown serve sub-command {}", cmd[0]));
                    }
                    continue;
                }
                Err(e) => {
                    // malformed SRV_PREDICT wire (short, or a row count
                    // that is NaN/negative/fractional/absurd): same
                    // treatment — no honest leader produces it
                    if self.sticky.is_none() {
                        self.sticky = Some(e);
                    }
                    continue;
                }
            };

            // per-batch, not per-session: a hot-swap may change D/Q
            let d = self.core.d();
            let q = self.core.q();
            let span = self.partition_for(nt, ranks).worker_span(rank);
            // the shard send is drained even on the failure paths below,
            // so the point-to-point channel stays clean for the next
            // batch; in streamed mode it may already sit in the back
            // buffer from the previous batch's prefetch
            let msg = match span {
                Some(_) => Some(match self.scratch.pending_shard.take() {
                    Some(m) => m,
                    None => comm.recv(0, TAG_XSTAR)?,
                }),
                None => None,
            };
            // streamed mode: the leader has already broadcast the next
            // batch's sub-command and shipped its shards — pull them
            // into the back buffer *before* this batch's compute, so
            // the compute overlaps the next batch's delivery instead of
            // idling for the leader's gather round-trip. A non-PREDICT
            // broadcast landing here (swap, done, refit, junk) is just
            // parked: the loop top handles it after this batch, which
            // is broadcast order.
            if stream {
                // lint: allow(no-alloc-hot-path) — empty receive sentinel
                let next = comm.bcast(0, Vec::new())?;
                if let Ok(Some((nt2, _))) = parse_predict(&next) {
                    if self.partition_for(nt2, ranks).worker_span(rank).is_some() {
                        self.scratch.pending_shard = Some(comm.recv(0, TAG_XSTAR)?);
                    }
                }
                self.scratch.pending_cmd = Some(next);
            }

            let scratch = &mut self.scratch;
            scratch.payload.clear();
            match span {
                None => scratch.payload.push(0.0), // no rows, success by definition
                Some(sp) => {
                    let rows = sp.len();
                    let msg = msg
                        .ok_or_else(|| anyhow!("shard missing for an owned span"))?;
                    if self.poisoned {
                        scratch.payload.push(1.0);
                    } else if msg.len() != rows * q {
                        // malformed shard wire: report through the
                        // fail-flagged gather instead of feeding a short
                        // buffer to `Mat::from_vec` (panic) or a long
                        // one to a silently wrong shard
                        scratch.payload.push(1.0);
                        if self.sticky.is_none() {
                            self.sticky = Some(anyhow!(
                                "shard wire length {} != {rows} rows × Q {q}",
                                msg.len()));
                        }
                    } else {
                        if scratch.xshard.rows() == rows && scratch.xshard.cols() == q {
                            scratch.xshard.set_from(&msg);
                        } else {
                            scratch.xshard = Mat::from_vec(rows, q, msg);
                        }
                        scratch.mean.clear();
                        scratch.mean.resize(rows * d, 0.0);
                        scratch.var.clear();
                        scratch.var.resize(rows, 0.0);
                        match backend.predict_batch(&self.core, &scratch.xshard, 0,
                                                    rows, &mut scratch.mean,
                                                    &mut scratch.var) {
                            Ok(()) => {
                                scratch.payload.extend_from_slice(&scratch.mean);
                                scratch.payload.extend_from_slice(&scratch.var);
                                scratch.payload.push(0.0);
                            }
                            Err(e) => {
                                scratch.payload.push(1.0);
                                if self.sticky.is_none() {
                                    self.sticky = Some(e);
                                }
                            }
                        }
                    }
                }
            }
            let _ = comm.gather(0, &scratch.payload)?;
        }
    }

    /// Leader: **posterior hot-swap** — broadcast a replacement core
    /// mid-session; every subsequent batch on every rank is evaluated
    /// against the new posterior. The cached row partition is unaffected
    /// (it depends only on batch size and rank count).
    pub fn rebroadcast(&mut self, core: PosteriorCore, comm: &mut Comm) -> Result<()> {
        let mut wire = Vec::with_capacity(
            1 + PosteriorCore::wire_len(core.q(), core.m(), core.d()));
        wire.push(SRV_SWAP);
        core.pack_into(&mut wire);
        comm.bcast(0, wire)?;
        self.core = core;
        Ok(())
    }

    /// Leader: ask every serving worker to leave the serve loop for one
    /// stats-only collective round ([`ServeSignal::Refit`] on their
    /// side). The caller runs the leader half of that collective next,
    /// then either [`rebroadcast`](DistributedPosterior::rebroadcast)s
    /// the rebuilt core or — if the refit failed — simply resumes
    /// issuing sub-commands against the old posterior.
    pub fn request_refit(&mut self, comm: &mut Comm) -> Result<()> {
        comm.bcast(0, vec![SRV_REFIT])?;
        Ok(())
    }

    /// Leader: close the session — workers return from
    /// [`serve`](DistributedPosterior::serve).
    pub fn finish(&mut self, comm: &mut Comm) -> Result<()> {
        comm.bcast(0, vec![SRV_DONE])?;
        Ok(())
    }
}

/// Worker half of a whole serving session in one call: receive the
/// posterior broadcast, then serve batches until the leader closes the
/// session. This is what the training cycle's worker loop calls when the
/// leader switches the cluster into serving mode.
pub fn worker_serve(comm: &mut Comm, backend: &mut dyn Backend) -> Result<()> {
    let mut dp = DistributedPosterior::worker(comm)?;
    dp.serve(comm, backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Cluster;
    use crate::coordinator::backend::RustCpuBackend;
    use crate::kern::RbfArd;
    use crate::math::stats::sgpr_stats_fwd;
    use crate::models::Posterior;
    use crate::testutil::prop::Rng64;

    fn toy_core(seed: u64) -> PosteriorCore {
        let (n, m, q, d) = (50usize, 8usize, 2usize, 3usize);
        let mut rng = Rng64::new(seed);
        let x = Mat::from_fn(n, q, |_, _| rng.normal());
        let y = Mat::from_fn(n, d, |_, _| rng.normal());
        let z = Mat::from_fn(m, q, |_, _| rng.normal());
        let kern = RbfArd::iso(1.2, 1.1, q);
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
        PosteriorCore::new(kern, z, 20.0, &st).unwrap()
    }

    /// Several batches (including a resize and an empty batch) through
    /// one session must each match the single-node posterior exactly.
    #[test]
    fn session_serves_multiple_batch_sizes() {
        let core = toy_core(42);
        let single = Posterior::from_core(core.clone());
        let mut rng = Rng64::new(43);
        let batches: Vec<Mat> = [17usize, 17, 0, 5]
            .iter()
            .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
            .collect();
        let expect: Vec<(Mat, Vec<f64>)> =
            batches.iter().map(|b| single.predict(b)).collect();

        for size in [1usize, 3, 4] {
            let core_ref = &core;
            let batches_ref = &batches;
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = RustCpuBackend;
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 4,
                                                             &mut comm).unwrap();
                    let mut out = Vec::new();
                    let mut mean = Mat::zeros(0, 0);
                    let mut var = Vec::new();
                    for b in batches_ref {
                        dp.predict_into(&mut comm, &mut backend, b, &mut mean,
                                        &mut var).unwrap();
                        out.push((mean.clone(), var.clone()));
                    }
                    dp.finish(&mut comm).unwrap();
                    Some(out)
                } else {
                    worker_serve(&mut comm, &mut backend).unwrap();
                    None
                }
            });
            let got = results[0].as_ref().expect("leader output");
            for (i, ((gm, gv), (em, ev))) in got.iter().zip(&expect).enumerate() {
                assert_eq!(gm.rows(), em.rows(), "size {size} batch {i}");
                if em.rows() > 0 {
                    assert!(gm.max_abs_diff(em) == 0.0, "size {size} batch {i}: mean");
                }
                assert_eq!(gv, ev, "size {size} batch {i}: var");
            }
        }
    }

    /// Regression: the row-partition cache must be keyed on
    /// **(batch size, rank count)**, not the batch size alone — a
    /// posterior reused against a different-sized communicator used to
    /// silently keep the old rank split. The cache now holds several
    /// recent keys (the streamed protocol's in-flight window), so
    /// alternating keys must all come back correct.
    #[test]
    fn partition_cache_keyed_on_batch_and_ranks() {
        let mut dp = DistributedPosterior {
            core: toy_core(46),
            rows_per_chunk: 2,
            parts: Vec::new(),
            builds: 0,
            scratch: ServeScratch::default(),
            sticky: None,
            poisoned: false,
        };
        assert_eq!(dp.partition_for(12, 2).workers(), 2);
        // same batch size, different comm size: must rebuild
        let p = dp.partition_for(12, 3);
        assert_eq!(p.workers(), 3);
        assert_eq!(p.n, 12);
        // same (nt, ranks): the cache holds
        assert_eq!(dp.partition_for(12, 3).workers(), 3);
        // same ranks, different batch size: must rebuild
        assert_eq!(dp.partition_for(7, 3).n, 7);
        // alternating keys inside the cache window stay correct
        for _ in 0..3 {
            assert_eq!(dp.partition_for(12, 3).n, 12);
            assert_eq!(dp.partition_for(7, 3).n, 7);
            assert_eq!(dp.partition_for(12, 2).workers(), 2);
        }
        assert_eq!(dp.partition_builds(), 3, "revisits must not rebuild");
        // overflow the LRU: the *least recently used* key (5, 4) is the
        // one evicted, recently touched keys survive
        assert_eq!(dp.partition_for(5, 4).n, 5);
        for nt in 100..100 + PARTITION_CACHE - 1 {
            assert_eq!(dp.partition_for(nt, 3).n, nt);
        }
        let builds = dp.partition_builds();
        assert_eq!(dp.partition_for(100, 3).n, 100); // still resident
        assert_eq!(dp.partition_builds(), builds, "LRU hit must not rebuild");
        assert_eq!(dp.partition_for(5, 4).n, 5); // evicted: rebuilt
        assert_eq!(dp.partition_builds(), builds + 1);
    }

    /// Regression for the serving front-end's traffic shape: a 100-batch
    /// stream of *ragged* sizes (whatever mix of client requests each
    /// deadline closed over) must not rebuild partitions O(batches)
    /// times. With the old 3-slot window, any 4+ recurring sizes
    /// thrashed — every lookup was a rebuild.
    #[test]
    fn ragged_batch_stream_does_not_thrash_partition_cache() {
        let mut dp = DistributedPosterior {
            core: toy_core(47),
            rows_per_chunk: 2,
            parts: Vec::new(),
            builds: 0,
            scratch: ServeScratch::default(),
            sticky: None,
            poisoned: false,
        };
        // six recurring ragged sizes — more than the old 3-slot window
        let sizes = [3usize, 8, 1, 13, 5, 21];
        for i in 0..100 {
            let nt = sizes[i % sizes.len()];
            assert_eq!(dp.partition_for(nt, 4).n, nt);
        }
        assert_eq!(dp.partition_builds(), sizes.len() as u64,
                   "a recurring mix of batch sizes must build each partition once");
    }

    /// Standalone hot-swap: after `rebroadcast`, every rank serves the
    /// replacement posterior — batches match the single-node posterior
    /// of the *new* core exactly, and differ from the old one.
    #[test]
    fn rebroadcast_swaps_the_served_posterior() {
        let core_a = toy_core(51);
        let core_b = toy_core(52); // independent fit: genuinely different
        let single_a = Posterior::from_core(core_a.clone());
        let single_b = Posterior::from_core(core_b.clone());
        let mut rng = Rng64::new(53);
        let xstar = Mat::from_fn(11, 2, |_, _| rng.normal());
        let (ea, _) = single_a.predict(&xstar);
        let (eb, evb) = single_b.predict(&xstar);
        assert!(ea.max_abs_diff(&eb) > 0.0, "cores must differ for the test to bite");

        for size in [1usize, 3, 4] {
            let (ca, cb, xs) = (&core_a, &core_b, &xstar);
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = RustCpuBackend;
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(ca.clone(), 3, &mut comm)
                        .unwrap();
                    let before = dp.predict(&mut comm, &mut backend, xs).unwrap();
                    dp.rebroadcast(cb.clone(), &mut comm).unwrap();
                    let after = dp.predict(&mut comm, &mut backend, xs).unwrap();
                    dp.finish(&mut comm).unwrap();
                    Some((before, after))
                } else {
                    worker_serve(&mut comm, &mut backend).unwrap();
                    None
                }
            });
            let (before, after) = results[0].as_ref().expect("leader output");
            assert!(before.0.max_abs_diff(&ea) == 0.0, "size {size}: pre-swap mean");
            assert!(after.0.max_abs_diff(&eb) == 0.0, "size {size}: post-swap mean");
            assert_eq!(after.1, evb, "size {size}: post-swap var");
        }
    }

    /// A malformed swap broadcast must not eject the worker
    /// mid-protocol: the session stays in lockstep, subsequent batches
    /// come back fail-flagged (never silently served from the stale
    /// core), and the sticky error at close names the swap.
    #[test]
    fn malformed_swap_wire_poisons_instead_of_desyncing() {
        let core = toy_core(60);
        let core_ref = &core;
        let mut rng = Rng64::new(61);
        let xstar = Mat::from_fn(6, 2, |_, _| rng.normal());
        let xs = &xstar;
        let results = Cluster::run(2, move |mut comm| {
            let mut backend = RustCpuBackend;
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(), 2,
                                                          &mut comm).unwrap();
                // corrupt swap: far too short to be a core wire
                comm.bcast(0, vec![SRV_SWAP, 1.0, 2.0]).unwrap();
                let err = dp.predict(&mut comm, &mut backend, xs)
                    .expect_err("poisoned worker must fail the batch");
                dp.finish(&mut comm).unwrap();
                Some(format!("{err:#}"))
            } else {
                let err = worker_serve(&mut comm, &mut backend)
                    .expect_err("worker must report the swap failure");
                assert!(format!("{err:#}").contains("posterior swap"),
                        "unhelpful error: {err:#}");
                None
            }
        });
        let msg = results[0].as_ref().expect("leader");
        assert!(msg.contains("rank 1"), "leader error must name the rank: {msg}");
    }

    /// A session-open wire whose core is corrupt must open the session
    /// poisoned (fail-flagged batches, lockstep preserved) rather than
    /// eject the worker before the first batch — the granularity header
    /// alone is enough to mirror the leader's shard sends.
    #[test]
    fn malformed_session_open_poisons_instead_of_desyncing() {
        let results = Cluster::run(2, |mut comm| {
            if comm.rank() == 0 {
                // corrupt session-open: valid granularity header (4
                // rows per chunk), junk core payload
                comm.bcast(0, vec![4.0, 1.0, 2.0]).unwrap();
                // one 8-row batch: rank 1 owns rows 4..8
                comm.bcast(0, vec![SRV_PREDICT, 8.0]).unwrap();
                comm.send(1, TAG_XSTAR, &[0.0; 8]).unwrap();
                let gathered = comm.gather(0, &[0.0]).unwrap().expect("root");
                comm.bcast(0, vec![SRV_DONE]).unwrap();
                Some(gathered[1].clone())
            } else {
                let mut backend = RustCpuBackend;
                let err = worker_serve(&mut comm, &mut backend)
                    .expect_err("worker must report the open failure");
                assert!(format!("{err:#}").contains("posterior broadcast"),
                        "unhelpful error: {err:#}");
                None
            }
        });
        // the batch came back fail-flagged, in lockstep
        assert_eq!(results[0].as_ref().expect("leader"), &vec![1.0]);
    }

    /// Streamed serving is a protocol reordering only: a stream of
    /// batches (including empty and tiny ones) must produce exactly the
    /// sequential outputs, and the session must keep serving sequential
    /// batches afterwards.
    #[test]
    fn streamed_session_matches_sequential_batches() {
        let core = toy_core(80);
        let single = Posterior::from_core(core.clone());
        let mut rng = Rng64::new(81);
        let batches: Vec<Mat> = [13usize, 0, 2, 13, 5]
            .iter()
            .map(|&nt| Mat::from_fn(nt, 2, |_, _| rng.normal()))
            .collect();
        let expect: Vec<(Mat, Vec<f64>)> =
            batches.iter().map(|b| single.predict(b)).collect();

        for size in [1usize, 3, 4] {
            let (core_ref, bs, exp) = (&core, &batches, &expect);
            let results = Cluster::run(size, move |mut comm| {
                let mut backend = RustCpuBackend;
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 3,
                                                              &mut comm).unwrap();
                    let streamed = dp.predict_stream(&mut comm, &mut backend, bs)
                        .unwrap();
                    // the session keeps serving sequentially afterwards
                    let tail = dp.predict(&mut comm, &mut backend, &bs[0]).unwrap();
                    dp.finish(&mut comm).unwrap();
                    Some((streamed, tail))
                } else {
                    worker_serve(&mut comm, &mut backend).unwrap();
                    None
                }
            });
            let (streamed, tail) = results[0].as_ref().expect("leader output");
            for (i, ((gm, gv), (em, ev))) in streamed.iter().zip(exp).enumerate() {
                assert_eq!(gm.rows(), em.rows(), "size {size} batch {i}");
                if em.rows() > 0 {
                    assert!(gm.max_abs_diff(em) == 0.0,
                            "size {size} batch {i}: streamed mean");
                }
                assert_eq!(gv, ev, "size {size} batch {i}: streamed var");
            }
            assert!(tail.0.max_abs_diff(&expect[0].0) == 0.0,
                    "size {size}: post-stream sequential batch");
            assert_eq!(tail.1, expect[0].1, "size {size}: post-stream var");
        }
    }

    /// Regression: an unknown sub-command verb or a short/corrupt
    /// `SRV_PREDICT` wire used to fall through to the predict path and
    /// index `cmd[1]` — a panic (cluster teardown) on short wires, a
    /// mis-serve otherwise. The worker must instead stay parked at the
    /// sub-command broadcast (lockstep: a real batch afterwards still
    /// serves exactly) and report the junk at close.
    #[test]
    fn unknown_verbs_and_short_command_wires_keep_lockstep() {
        let core = toy_core(90);
        let single = Posterior::from_core(core.clone());
        let mut rng = Rng64::new(91);
        let xstar = Mat::from_fn(6, 2, |_, _| rng.normal());
        let (em, ev) = single.predict(&xstar);

        let (core_ref, xs) = (&core, &xstar);
        let results = Cluster::run(2, move |mut comm| {
            let mut backend = RustCpuBackend;
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(), 2,
                                                          &mut comm).unwrap();
                comm.bcast(0, vec![7.25, 1.0]).unwrap();   // unknown verb
                comm.bcast(0, vec![SRV_PREDICT]).unwrap(); // short predict wire
                comm.bcast(0, vec![SRV_PREDICT, f64::NAN, 0.0]).unwrap(); // NaN rows
                comm.bcast(0, vec![SRV_PREDICT, -4.0, 0.0]).unwrap();     // negative
                comm.bcast(0, vec![SRV_PREDICT, 1e300, 0.0]).unwrap();    // absurd
                // corrupt but integral and allocatable-looking: must be
                // rejected by the sanity cap, not partitioned (OOM)
                comm.bcast(0, vec![SRV_PREDICT, 3.0e9, 0.0]).unwrap();
                // lockstep held: a real batch still serves exactly
                let out = dp.predict(&mut comm, &mut backend, xs).unwrap();
                dp.finish(&mut comm).unwrap();
                Some(out)
            } else {
                let err = worker_serve(&mut comm, &mut backend)
                    .expect_err("junk verbs must be reported");
                assert!(format!("{err:#}").contains("unknown serve sub-command"),
                        "unhelpful error: {err:#}");
                None
            }
        });
        let (gm, gv) = results[0].as_ref().expect("leader output");
        assert!(gm.max_abs_diff(&em) == 0.0, "post-junk batch must serve exactly");
        assert_eq!(gv, &ev);
    }

    /// A poisoned worker inside a stream fail-flags every in-flight
    /// batch (the stream returns the first error but completes the
    /// protocol), and a good swap afterwards restores full service —
    /// the session is never torn down.
    #[test]
    fn stream_with_poisoned_worker_fails_cleanly_and_recovers() {
        let core_a = toy_core(95);
        let core_b = toy_core(96);
        let single_b = Posterior::from_core(core_b.clone());
        let mut rng = Rng64::new(97);
        let b0 = Mat::from_fn(6, 2, |_, _| rng.normal());
        let b1 = Mat::from_fn(4, 2, |_, _| rng.normal());
        let expect: Vec<(Mat, Vec<f64>)> =
            [&b0, &b1].iter().map(|b| single_b.predict(b)).collect();

        let (ca, cb, b0r, b1r, exp) = (&core_a, &core_b, &b0, &b1, &expect);
        let results = Cluster::run(2, move |mut comm| {
            let mut backend = RustCpuBackend;
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(ca.clone(), 2, &mut comm)
                    .unwrap();
                // corrupt swap wire: rank 1's session is poisoned
                comm.bcast(0, vec![SRV_SWAP, 1.0, 2.0]).unwrap();
                let err = dp
                    .predict_stream(&mut comm, &mut backend,
                                    &[b0r.clone(), b1r.clone()])
                    .expect_err("poisoned worker must fail the stream");
                assert!(format!("{err:#}").contains("stream batch 0"),
                        "first error must win: {err:#}");
                // a good swap clears the poison; the stream serves again
                dp.rebroadcast(cb.clone(), &mut comm).unwrap();
                let outs = dp
                    .predict_stream(&mut comm, &mut backend,
                                    &[b0r.clone(), b1r.clone()])
                    .unwrap();
                dp.finish(&mut comm).unwrap();
                Some(outs)
            } else {
                let err = worker_serve(&mut comm, &mut backend)
                    .expect_err("worker must report the corrupt swap");
                assert!(format!("{err:#}").contains("posterior swap"),
                        "unhelpful error: {err:#}");
                None
            }
        });
        let outs = results[0].as_ref().expect("leader output");
        for (i, ((gm, gv), (em, ev))) in outs.iter().zip(exp).enumerate() {
            assert!(gm.max_abs_diff(em) == 0.0, "recovered stream batch {i}: mean");
            assert_eq!(gv, ev, "recovered stream batch {i}: var");
        }
    }

    /// A batch smaller than the rank count leaves trailing ranks without
    /// rows; they must still stay in lockstep.
    #[test]
    fn tiny_batches_leave_ranks_idle_but_synchronised() {
        let core = toy_core(44);
        let single = Posterior::from_core(core.clone());
        let mut rng = Rng64::new(45);
        let xstar = Mat::from_fn(2, 2, |_, _| rng.normal());
        let (em, ev) = single.predict(&xstar);

        let core_ref = &core;
        let xs = &xstar;
        let results = Cluster::run(5, move |mut comm| {
            let mut backend = RustCpuBackend;
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(), 1, &mut comm)
                    .unwrap();
                let out = dp.predict(&mut comm, &mut backend, xs).unwrap();
                dp.finish(&mut comm).unwrap();
                Some(out)
            } else {
                worker_serve(&mut comm, &mut backend).unwrap();
                None
            }
        });
        let (gm, gv) = results[0].as_ref().expect("leader output");
        assert!(gm.max_abs_diff(&em) == 0.0);
        assert_eq!(gv, &ev);
    }
}
