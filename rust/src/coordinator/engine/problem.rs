//! Problem statement + parameter-vector layout.
//!
//! Everything the optimiser sees is one flat `Vec<f64>`:
//!
//!   [ view 0: log_hyp (Q+1) | log β | Z (M·Q) ] … [ view V−1: … ]
//!   [ μ (N·Q) | log S (N·Q) ]          (variational problems only)
//!
//! `ParamLayout` (crate-internal) is the single source of truth for
//! those offsets; the cycle and the trainer never hand-compute them.

use crate::data::store::ChunkSource;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// A view's observations: either a resident `N × D_v` matrix (the
/// historical path, still what every variational problem uses) or a
/// chunk store streamed on demand so a rank's working set stays
/// O(chunk) instead of O(N/P).
#[derive(Clone)]
pub enum ViewData {
    /// Resident matrix, fully in memory.
    Resident(Mat),
    /// Manifest-backed chunk store; payloads are pulled per chunk.
    Store(Arc<dyn ChunkSource>),
}

impl std::fmt::Debug for ViewData {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ViewData::Resident(m) => {
                write!(f, "Resident({}×{})", m.rows(), m.cols())
            }
            ViewData::Store(s) => {
                let m = s.manifest();
                write!(f, "Store({}×{}, q={}, {} chunks)",
                       m.n, m.d, m.q, m.num_chunks())
            }
        }
    }
}

impl From<Mat> for ViewData {
    fn from(m: Mat) -> Self {
        ViewData::Resident(m)
    }
}

impl ViewData {
    /// Datapoint count N.
    pub fn rows(&self) -> usize {
        match self {
            ViewData::Resident(m) => m.rows(),
            ViewData::Store(s) => s.manifest().n,
        }
    }

    /// Output dimensionality D_v.
    pub fn cols(&self) -> usize {
        match self {
            ViewData::Resident(m) => m.cols(),
            ViewData::Store(s) => s.manifest().d,
        }
    }

    /// The resident matrix, if this view is resident.
    pub fn resident(&self) -> Option<&Mat> {
        match self {
            ViewData::Resident(m) => Some(m),
            ViewData::Store(_) => None,
        }
    }

    /// The chunk store, if this view is store-backed.
    pub fn store(&self) -> Option<&Arc<dyn ChunkSource>> {
        match self {
            ViewData::Resident(_) => None,
            ViewData::Store(s) => Some(s),
        }
    }

    /// Is this view streamed from a chunk store?
    pub fn is_store(&self) -> bool {
        matches!(self, ViewData::Store(_))
    }
}

/// One observed view: outputs plus per-view kernel/noise/inducing state.
#[derive(Clone, Debug)]
pub struct ViewSpec {
    /// N × D_v observations (resident or store-backed).
    pub y: ViewData,
    /// Initial inducing inputs, M × Q.
    pub z0: Mat,
    /// Initial kernel hyperparameters.
    pub kern0: RbfArd,
    /// Initial noise precision β.
    pub beta0: f64,
    /// AOT config name for the XLA backend (e.g. "paper").
    pub aot_config: String,
}

/// The latent-input specification shared by all views.
#[derive(Clone, Debug)]
pub enum LatentSpec {
    /// Supervised: X observed (N × Q), resident.
    Observed(Mat),
    /// Supervised: X observed, riding in view 0's chunk store (its x
    /// block) — each rank streams its own chunks' inputs together with
    /// the outputs, so X is never materialized anywhere.
    ObservedStore,
    /// Unsupervised: variational q(x_n) = N(μ_n, diag S_n).
    Variational { mu0: Mat, s0: Mat },
}

impl LatentSpec {
    /// Does q(X) carry optimisable variational parameters?
    pub fn is_variational(&self) -> bool {
        matches!(self, LatentSpec::Variational { .. })
    }
}

/// A complete inference problem.
#[derive(Clone, Debug)]
pub struct Problem {
    /// The latent-input specification shared by all views.
    pub latent: LatentSpec,
    /// The observed views (one for SGPR/BGP-LVM, several for MRD).
    pub views: Vec<ViewSpec>,
    /// Latent dimensionality Q.
    pub q: usize,
}

impl Problem {
    /// Datapoint count N.
    pub fn n(&self) -> usize {
        self.views[0].y.rows()
    }

    /// The packed optimiser parameter vector at the problem's initial
    /// state — the flat layout every rank agrees on, as accepted by
    /// [`DistributedEvaluator::eval`](super::cycle::DistributedEvaluator::eval)
    /// and [`stats_pass`](super::cycle::DistributedEvaluator::stats_pass).
    pub fn initial_params(&self) -> Vec<f64> {
        ParamLayout::new(self).initial_params(self)
    }

    pub(crate) fn validate(&self) -> Result<()> {
        let n = self.n();
        for (v, view) in self.views.iter().enumerate() {
            if view.y.rows() != n {
                return Err(anyhow!("view {v}: {} rows, expected {n}", view.y.rows()));
            }
            if view.z0.cols() != self.q || view.kern0.q() != self.q {
                return Err(anyhow!("view {v}: Q mismatch"));
            }
            // Store-backed views stream X and Y together per chunk, which
            // only makes sense when the latents are the store's x block:
            // variational problems scatter an O(N/P) (μ,S) span by protocol
            // and so cannot run O(chunk); resident-X + store-Y would split
            // one logical row across two sources.
            if view.y.is_store() {
                if self.views.len() != 1 {
                    return Err(anyhow!(
                        "store-backed views support exactly one view (got {})",
                        self.views.len()));
                }
                if !matches!(self.latent, LatentSpec::ObservedStore) {
                    return Err(anyhow!(
                        "store-backed view requires LatentSpec::ObservedStore"));
                }
            }
        }
        match &self.latent {
            LatentSpec::Observed(x) => {
                if x.rows() != n || x.cols() != self.q {
                    return Err(anyhow!("X shape mismatch"));
                }
            }
            LatentSpec::ObservedStore => {
                let man = match self.views[0].y.store() {
                    Some(s) => s.manifest(),
                    None => return Err(anyhow!(
                        "ObservedStore latent requires a store-backed view 0")),
                };
                if man.q == 0 || man.q != self.q {
                    return Err(anyhow!(
                        "store has q={} x-columns, problem wants q={}",
                        man.q, self.q));
                }
            }
            LatentSpec::Variational { mu0, s0 } => {
                if mu0.rows() != n || mu0.cols() != self.q
                    || s0.rows() != n || s0.cols() != self.q {
                    return Err(anyhow!("mu0/s0 shape mismatch"));
                }
            }
        }
        Ok(())
    }
}

/// Fitted parameters after training.
#[derive(Clone, Debug)]
pub struct Fitted {
    /// Per-view fitted kernels.
    pub kerns: Vec<RbfArd>,
    /// Per-view fitted noise precisions β.
    pub betas: Vec<f64>,
    /// Per-view fitted inducing inputs (M × Q).
    pub zs: Vec<Mat>,
    /// Posterior means (variational) or the observed X (supervised,
    /// resident). Empty (0 × 0) for store-backed problems — X stays on
    /// disk; read it through the store if needed.
    pub mu: Mat,
    /// Posterior variances (variational) — empty for supervised.
    pub s: Mat,
}

// ---------------------------------------------------------------------
// parameter packing
// ---------------------------------------------------------------------

/// Offsets into the optimiser's flat parameter vector.
pub(crate) struct ParamLayout {
    pub q: usize,
    pub m: usize,
    pub views: usize,
    pub n: usize,
    pub variational: bool,
}

impl ParamLayout {
    pub fn new(problem: &Problem) -> ParamLayout {
        ParamLayout {
            q: problem.q,
            m: problem.views[0].z0.rows(),
            views: problem.views.len(),
            n: problem.n(),
            variational: problem.latent.is_variational(),
        }
    }

    pub fn view_len(&self) -> usize {
        (self.q + 1) + 1 + self.m * self.q
    }

    pub fn len(&self) -> usize {
        self.views * self.view_len()
            + if self.variational { 2 * self.n * self.q } else { 0 }
    }

    /// Length of the global (per-view) prefix broadcast to workers.
    pub fn global_len(&self) -> usize {
        self.views * self.view_len()
    }

    /// (log_hyp, log_beta, z) slices of view v.
    pub fn view_parts<'a>(&self, x: &'a [f64], v: usize) -> (&'a [f64], f64, &'a [f64]) {
        let o = v * self.view_len();
        let h = &x[o..o + self.q + 1];
        let b = x[o + self.q + 1];
        let z = &x[o + self.q + 2..o + self.view_len()];
        (h, b, z)
    }

    pub fn mu_slice<'a>(&self, x: &'a [f64]) -> &'a [f64] {
        let o = self.views * self.view_len();
        &x[o..o + self.n * self.q]
    }

    pub fn log_s_slice<'a>(&self, x: &'a [f64]) -> &'a [f64] {
        let o = self.views * self.view_len() + self.n * self.q;
        &x[o..o + self.n * self.q]
    }

    /// Pack a problem's initial state into the optimiser vector.
    pub fn initial_params(&self, problem: &Problem) -> Vec<f64> {
        let mut x = Vec::with_capacity(self.len());
        for view in &problem.views {
            x.extend(view.kern0.to_log_hyp());
            x.push(view.beta0.ln());
            x.extend_from_slice(view.z0.as_slice());
        }
        if let LatentSpec::Variational { mu0, s0 } = &problem.latent {
            x.extend_from_slice(mu0.as_slice());
            x.extend(s0.as_slice().iter().map(|s| s.ln()));
        }
        x
    }

    /// Unpack the optimised vector into user-facing fitted parameters.
    pub fn unpack_fitted(&self, problem: &Problem, x: &[f64]) -> Fitted {
        let globals = unpack_globals(self, x);
        Fitted {
            kerns: globals.views.iter().map(|v| RbfArd::from_log_hyp(&v.log_hyp)).collect(),
            betas: globals.views.iter().map(|v| v.log_beta.exp()).collect(),
            zs: globals.views.iter().map(|v| v.z.clone()).collect(),
            mu: if self.variational {
                Mat::from_vec(self.n, self.q, self.mu_slice(x).to_vec())
            } else {
                match &problem.latent {
                    LatentSpec::Observed(xobs) => xobs.clone(),
                    LatentSpec::ObservedStore => Mat::zeros(0, 0),
                    _ => unreachable!(),
                }
            },
            s: if self.variational {
                Mat::from_vec(self.n, self.q,
                              self.log_s_slice(x).iter().map(|v| v.exp()).collect())
            } else {
                Mat::zeros(0, 0)
            },
        }
    }
}

/// Per-view globals as unpacked on every rank each evaluation.
pub(crate) struct GlobalView {
    pub log_hyp: Vec<f64>,
    pub log_beta: f64,
    pub z: Mat,
}

pub(crate) struct GlobalParams {
    pub views: Vec<GlobalView>,
}

pub(crate) fn unpack_globals(layout: &ParamLayout, x: &[f64]) -> GlobalParams {
    let views = (0..layout.views)
        .map(|v| {
            let (h, b, z) = layout.view_parts(x, v);
            GlobalView {
                log_hyp: h.to_vec(),
                log_beta: b,
                z: Mat::from_vec(layout.m, layout.q, z.to_vec()),
            }
        })
        .collect();
    GlobalParams { views }
}

/// The leader broadcasts only the global prefix of the parameter vector;
/// workers never need μ/logS in packed form, so pad with zeros to reuse
/// `unpack_globals`.
pub(crate) fn pad_globals(layout: &ParamLayout, gx: &[f64]) -> Vec<f64> {
    let mut x = vec![0.0; layout.len()];
    x[..gx.len()].copy_from_slice(gx);
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_problem(variational: bool) -> Problem {
        let (n, q, m, d) = (6, 2, 3, 2);
        let y = Mat::from_fn(n, d, |i, j| (i * d + j) as f64 * 0.1);
        let latent = if variational {
            LatentSpec::Variational {
                mu0: Mat::from_fn(n, q, |i, j| (i + j) as f64 * 0.2),
                s0: Mat::from_vec(n, q, vec![0.5; n * q]),
            }
        } else {
            LatentSpec::Observed(Mat::from_fn(n, q, |i, j| (i + 2 * j) as f64 * 0.3))
        };
        Problem {
            latent,
            views: vec![ViewSpec {
                y: y.into(),
                z0: Mat::from_fn(m, q, |i, j| (i as f64) - (j as f64)),
                kern0: RbfArd::iso(1.5, 0.7, q),
                beta0: 4.0,
                aot_config: "test".into(),
            }],
            q,
        }
    }

    #[test]
    fn layout_roundtrips_initial_params() {
        for variational in [false, true] {
            let p = toy_problem(variational);
            p.validate().unwrap();
            let layout = ParamLayout::new(&p);
            let x = layout.initial_params(&p);
            assert_eq!(x.len(), layout.len());

            let globals = unpack_globals(&layout, &x);
            assert_eq!(globals.views.len(), 1);
            assert!((globals.views[0].log_beta - 4.0f64.ln()).abs() < 1e-15);
            assert!(globals.views[0].z.max_abs_diff(&p.views[0].z0) == 0.0);

            let fitted = layout.unpack_fitted(&p, &x);
            assert!((fitted.betas[0] - 4.0).abs() < 1e-12);
            assert!((fitted.kerns[0].variance - 1.5).abs() < 1e-12);
            if variational {
                if let LatentSpec::Variational { mu0, s0 } = &p.latent {
                    assert!(fitted.mu.max_abs_diff(mu0) == 0.0);
                    assert!(fitted.s.max_abs_diff(s0) < 1e-12);
                }
            } else {
                assert_eq!(fitted.s.rows(), 0);
            }
        }
    }

    #[test]
    fn validation_rejects_shape_mismatches() {
        let mut p = toy_problem(true);
        p.q = 3; // views were built for q = 2
        assert!(p.validate().is_err());

        let mut p = toy_problem(false);
        if let LatentSpec::Observed(x) = &mut p.latent {
            *x = Mat::zeros(2, 2); // wrong N
        }
        assert!(p.validate().is_err());
    }

    #[test]
    fn validation_gates_store_backed_views() {
        use crate::data::store::ResidentStore;
        let n = 6;
        let x = Mat::from_fn(n, 2, |i, j| (i + j) as f64 * 0.3);
        let y = Mat::from_fn(n, 2, |i, j| (i * 2 + j) as f64 * 0.1);
        let store: Arc<dyn ChunkSource> = Arc::new(
            ResidentStore::from_mats(Some(x), y, 4).unwrap());

        let mut p = toy_problem(false);
        p.views[0].y = ViewData::Store(Arc::clone(&store));
        // store-backed view with resident-X latent: rejected
        assert!(p.validate().is_err());
        // the matching latent makes it valid
        p.latent = LatentSpec::ObservedStore;
        p.validate().unwrap();
        assert!(!p.latent.is_variational());
        assert_eq!((p.n(), p.views[0].y.cols()), (n, 2));
        // variational latents cannot stream (the (μ,S) span scatter is
        // O(N/P) by protocol)
        p.latent = LatentSpec::Variational {
            mu0: Mat::zeros(n, 2),
            s0: Mat::from_vec(n, 2, vec![0.5; n * 2]),
        };
        assert!(p.validate().is_err());
        // ObservedStore without a store-backed view 0: rejected
        let mut p = toy_problem(false);
        p.latent = LatentSpec::ObservedStore;
        assert!(p.validate().is_err());
    }

    #[test]
    fn store_fitted_leaves_x_on_disk() {
        use crate::data::store::ResidentStore;
        let n = 6;
        let x = Mat::from_fn(n, 2, |i, j| (i + j) as f64 * 0.3);
        let y = Mat::from_fn(n, 2, |i, j| (i * 2 + j) as f64 * 0.1);
        let mut p = toy_problem(false);
        p.views[0].y = ViewData::Store(Arc::new(
            ResidentStore::from_mats(Some(x), y, 4).unwrap()));
        p.latent = LatentSpec::ObservedStore;
        let layout = ParamLayout::new(&p);
        let v = layout.initial_params(&p);
        let fitted = layout.unpack_fitted(&p, &v);
        assert_eq!((fitted.mu.rows(), fitted.s.rows()), (0, 0));
    }

    #[test]
    fn global_prefix_padding_reconstructs_views() {
        let p = toy_problem(true);
        let layout = ParamLayout::new(&p);
        let x = layout.initial_params(&p);
        let gx = &x[..layout.global_len()];
        let padded = pad_globals(&layout, gx);
        let a = unpack_globals(&layout, &x);
        let b = unpack_globals(&layout, &padded);
        assert!(a.views[0].z.max_abs_diff(&b.views[0].z) == 0.0);
        assert_eq!(a.views[0].log_hyp, b.views[0].log_hyp);
    }
}
