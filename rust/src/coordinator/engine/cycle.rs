//! The eight-step SPMD evaluation cycle (DESIGN.md §4) as a reusable
//! component:
//!
//!   1–3. leader broadcasts command + global parameters, ships each
//!        rank its (μ, S) span            (`bcast` / tagged sends)
//!   4.   every rank: per-chunk stats_fwd (batched through the backend,
//!        fanned across threads on `parallel-cpu`) → tree `reduce_sum`
//!   5.   leader: indistributable M×M core (bound + cotangents)
//!   5b.  leader broadcasts cotangents    (`bcast`; empty = abort cycle)
//!   6.   every rank: per-chunk stats_vjp → tree `reduce_sum` of the
//!        global (Z, hyp) partials
//!   7.   `gather` of the span-local (dμ, d log S) gradients
//!   8.   (in `train`) optimiser step at the leader
//!
//! [`DistributedEvaluator`] owns one rank's half of that conversation:
//! the leader drives it through [`DistributedEvaluator::eval`], workers
//! sit in [`DistributedEvaluator::serve`]. Both sides keep the
//! collectives in lockstep even when a rank's compute fails mid-cycle:
//! failures ride a trailing fail-count element on each reduction, and a
//! leader-side failure aborts the cycle with an empty cotangent
//! broadcast — so an error surfaces as an `Err` on the optimiser's next
//! step instead of a protocol desync.

use super::problem::{pad_globals, unpack_globals, GlobalParams, LatentSpec, ParamLayout,
                     Problem};
use super::train::EngineConfig;
use crate::collectives::Comm;
use crate::config::BackendKind;
use crate::coordinator::backend::{make_backends, Backend, ChunkData, ChunkTask, ViewParams};
use crate::coordinator::partition::{ChunkRange, Partition};
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::bound::bound_and_grads;
use crate::math::stats::{Stats, StatsCts};
use crate::metrics::{thread_cpu_time, Phase, PhaseTimer};
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};
use std::time::Instant;

// ---------------------------------------------------------------------
// wire protocol
// ---------------------------------------------------------------------

const CMD_EVAL: f64 = 1.0;
const CMD_STOP: f64 = 0.0;
const TAG_LOCALS: u64 = 100;

/// Payload length of the per-view statistics, excluding the trailing
/// fail-count element.
fn stats_wire_len(m: usize, ds: &[usize]) -> usize {
    ds.iter().map(|d| 4 + m * d + m * m).sum()
}

fn cts_wire_len(m: usize, ds: &[usize]) -> usize {
    ds.iter().map(|d| 3 + m * d + m * m).sum()
}

/// Payload length of the global-gradient partials (dZ + dhyp per view),
/// excluding the trailing fail-count element.
fn grads_wire_len(m: usize, q: usize, views: usize) -> usize {
    views * (m * q + q + 1)
}

/// Append the fail flag reducers sum into a fail count: `Some(payload)`
/// from a rank whose compute succeeded, `None` (zero-filled to `len`) from
/// one whose compute failed. Both sides of the protocol — leader `eval`
/// and worker `serve` — pack through this one helper so the wire format
/// cannot drift between them.
fn pack_with_flag(payload: Option<Vec<f64>>, len: usize) -> Vec<f64> {
    match payload {
        Some(mut wire) => {
            debug_assert_eq!(wire.len(), len, "wire payload length");
            wire.push(0.0);
            wire
        }
        None => {
            let mut wire = vec![0.0; len + 1];
            wire[len] = 1.0;
            wire
        }
    }
}

fn pack_stats(stats: &[Stats]) -> Vec<f64> {
    let mut wire = Vec::new();
    for st in stats {
        wire.extend(st.pack());
    }
    wire
}

fn pack_grads(view_grads: &[(Mat, Vec<f64>)]) -> Vec<f64> {
    let mut wire = Vec::new();
    for (dz, dhyp) in view_grads {
        wire.extend_from_slice(dz.as_slice());
        wire.extend_from_slice(dhyp);
    }
    wire
}

// ---------------------------------------------------------------------
// per-rank worker state
// ---------------------------------------------------------------------

/// Per-rank state: resident chunks (one fully-assembled `ChunkData` per
/// view per chunk — mask, supervised x and the view's Y tile attached at
/// build time, so nothing static is copied on the evaluation hot path)
/// and a backend per view.
struct WorkerState {
    /// `view_chunks[v][c]` — chunk c's data for view v.
    view_chunks: Vec<Vec<ChunkData>>,
    backends: Vec<Box<dyn Backend>>,
    /// Runtime kept alive for the XLA backends (owns the PJRT client).
    _runtime: Option<Runtime>,
    span: Option<ChunkRange>,
    q: usize,
    variational: bool,
}

/// Slice one chunk's (μ, S) rows out of the rank's span-local buffers,
/// padding the tail (μ = 0, S = 1).
fn chunk_latent(chunk: &ChunkData, span_start: usize, q: usize,
                mu_span: &[f64], s_span: &[f64], c: usize) -> (Mat, Mat) {
    let off = (chunk.start - span_start) * q;
    let live = chunk.live * q;
    let mut mu = Mat::zeros(c, q);
    let mut s = Mat::from_vec(c, q, vec![1.0; c * q]);
    mu.as_mut_slice()[..live].copy_from_slice(&mu_span[off..off + live]);
    s.as_mut_slice()[..live].copy_from_slice(&s_span[off..off + live]);
    (mu, s)
}

/// Assemble one view's batch: each resident chunk (borrowed) with its
/// (μ, S) slice attached. `latent_start` is the rank's span start for
/// variational problems, `None` for supervised ones.
fn view_tasks<'a>(chunks: &'a [ChunkData], latent_start: Option<usize>, q: usize,
                  mu_span: &[f64], s_span: &[f64], c: usize) -> Vec<ChunkTask<'a>> {
    chunks
        .iter()
        .map(|chunk| ChunkTask {
            chunk,
            latent: latent_start.map(|start| chunk_latent(chunk, start, q, mu_span,
                                                          s_span, c)),
        })
        .collect()
}

impl WorkerState {
    fn build(problem: &Problem, cfg: &EngineConfig, part: &Partition, rank: usize)
             -> Result<WorkerState> {
        let q = problem.q;
        let c = part.chunk;
        let ranges = &part.per_worker[rank];
        let variational = problem.latent.is_variational();

        // chunk skeletons (mask + supervised x)
        let mut skeletons = Vec::with_capacity(ranges.len());
        for r in ranges {
            let live = r.len();
            let mut w = vec![0.0; c];
            w[..live].fill(1.0);
            let x = match &problem.latent {
                LatentSpec::Observed(x_all) => {
                    let mut x = Mat::zeros(c, q);
                    for i in 0..live {
                        x.row_mut(i).copy_from_slice(x_all.row(r.start + i));
                    }
                    x
                }
                LatentSpec::Variational { .. } => Mat::zeros(0, 0),
            };
            skeletons.push(ChunkData { start: r.start, live, y: Mat::zeros(0, 0), x, w });
        }

        // per-view resident chunks: skeleton + the view's padded Y tile
        let mut view_chunks = Vec::with_capacity(problem.views.len());
        for view in &problem.views {
            let d = view.y.cols();
            let mut chunks = Vec::with_capacity(ranges.len());
            for (r, skel) in ranges.iter().zip(&skeletons) {
                let mut y = Mat::zeros(c, d);
                for i in 0..r.len() {
                    y.row_mut(i).copy_from_slice(view.y.row(r.start + i));
                }
                let mut chunk = skel.clone();
                chunk.y = y;
                chunks.push(chunk);
            }
            view_chunks.push(chunks);
        }

        // backends, via the kind-keyed factory
        let aot_configs: Vec<String> =
            problem.views.iter().map(|v| v.aot_config.clone()).collect();
        let (backends, runtime) =
            make_backends(cfg.backend, &aot_configs, &cfg.artifacts_dir)?;

        Ok(WorkerState {
            view_chunks,
            backends,
            _runtime: runtime,
            span: part.worker_span(rank),
            q,
            variational,
        })
    }

    /// The rank's span start when (μ, S) slices must be attached.
    fn latent_start(&self) -> Option<usize> {
        if self.variational {
            self.span.map(|s| s.start)
        } else {
            None
        }
    }

    /// One full local forward pass: per-view stats summed over chunks
    /// (in chunk order, regardless of how the backend parallelised them).
    fn local_fwd(&mut self, globals: &GlobalParams, mu_span: &[f64], s_span: &[f64],
                 c: usize, m: usize, ds: &[usize]) -> Result<Vec<Stats>> {
        let latent_start = self.latent_start();
        let mut out = Vec::with_capacity(globals.views.len());
        for (v, gv) in globals.views.iter().enumerate() {
            let tasks = view_tasks(&self.view_chunks[v], latent_start, self.q,
                                   mu_span, s_span, c);
            let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
            // KL is counted exactly once: attached to view 0.
            let include_kl = self.variational && v == 0;
            let stats = self.backends[v].stats_fwd_batch(&tasks, &vp, include_kl)?;
            // ds[v] (not the local tile width): ranks with zero chunks must
            // still pack wire vectors of the global shape for the reducer.
            let mut acc = Stats::zeros(m, ds[v]);
            let mut first = true;
            for st in stats {
                if first {
                    acc = st;
                    first = false;
                } else {
                    acc.add_assign(&st);
                }
            }
            out.push(acc);
        }
        Ok(out)
    }

    /// One full local VJP pass. Returns (per-view (dz, dhyp) partials,
    /// span-local dμ, span-local d log S).
    fn local_vjp(&mut self, globals: &GlobalParams, all_cts: &[StatsCts],
                 mu_span: &[f64], s_span: &[f64], c: usize, m: usize)
                 -> Result<(Vec<(Mat, Vec<f64>)>, Vec<f64>, Vec<f64>)> {
        let latent_start = self.latent_start();
        let span_len = self.span.map(|s| s.len()).unwrap_or(0);
        let mut dmu_span = vec![0.0; span_len * self.q];
        let mut dls_span = vec![0.0; span_len * self.q];
        let mut view_grads = Vec::with_capacity(globals.views.len());

        for (v, gv) in globals.views.iter().enumerate() {
            let tasks = view_tasks(&self.view_chunks[v], latent_start, self.q,
                                   mu_span, s_span, c);
            let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
            let grads = self.backends[v].stats_vjp_batch(&tasks, &vp, &all_cts[v])?;

            let mut dz = Mat::zeros(m, self.q);
            let mut dhyp = vec![0.0; self.q + 1];
            for (task, g) in tasks.iter().zip(&grads) {
                if let Some(span_start) = latent_start {
                    // accumulate local grads (chain dS -> dlogS needs S)
                    let (_, s) = task.latent().expect("variational task has latent");
                    let off = (task.chunk.start - span_start) * self.q;
                    for i in 0..task.chunk.live * self.q {
                        dmu_span[off + i] += g.dmu.as_slice()[i];
                        dls_span[off + i] += g.ds.as_slice()[i] * s.as_slice()[i];
                    }
                }
                dz.axpy(1.0, &g.dz);
                for (a, b) in dhyp.iter_mut().zip(&g.dhyp) {
                    *a += b;
                }
            }
            view_grads.push((dz, dhyp));
        }
        Ok((view_grads, dmu_span, dls_span))
    }
}

// ---------------------------------------------------------------------
// the evaluator
// ---------------------------------------------------------------------

/// One rank's half of the distributed evaluation cycle. Rank 0 (the
/// leader) calls [`eval`](DistributedEvaluator::eval) once per objective
/// evaluation and [`finish`](DistributedEvaluator::finish) when done;
/// every other rank parks in [`serve`](DistributedEvaluator::serve).
pub struct DistributedEvaluator {
    comm: Comm,
    state: WorkerState,
    layout: ParamLayout,
    /// Output width per view (global, identical on every rank).
    ds: Vec<usize>,
    /// Fixed chunk size C.
    chunk: usize,
    /// Every rank's datapoint span (for scattering (μ,S) and gathering
    /// their gradients).
    spans: Vec<Option<ChunkRange>>,
    timer: PhaseTimer,
    /// Distributable compute consumed by this rank (seconds).
    compute: f64,
    /// Measure compute as wall-clock (intra-rank fan-out spreads the work
    /// over threads the rank-thread CPU clock cannot see) vs thread CPU
    /// time (serial backends on a time-shared host).
    compute_wall: bool,
}

impl DistributedEvaluator {
    /// Build this rank's state (chunks, tiles, backends) and bind it to
    /// the communicator.
    pub fn new(problem: &Problem, cfg: &EngineConfig, part: &Partition, comm: Comm)
               -> Result<DistributedEvaluator> {
        let rank = comm.rank();
        let state = WorkerState::build(problem, cfg, part, rank)?;
        let layout = ParamLayout::new(problem);
        let ds = problem.views.iter().map(|v| v.y.cols()).collect();
        let spans = (0..part.workers()).map(|r| part.worker_span(r)).collect();
        let compute_wall = matches!(cfg.backend, BackendKind::ParallelCpu { .. });
        Ok(DistributedEvaluator {
            comm,
            state,
            layout,
            ds,
            chunk: cfg.chunk,
            spans,
            timer: PhaseTimer::new(),
            compute: 0.0,
            compute_wall,
        })
    }

    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// Phase timings accumulated on this rank.
    pub fn timer(&self) -> &PhaseTimer {
        &self.timer
    }

    pub fn bytes_sent(&self) -> u64 {
        self.comm.bytes_sent()
    }

    pub fn messages_sent(&self) -> u64 {
        self.comm.messages_sent()
    }

    /// Number of optimisable parameters.
    pub fn n_params(&self) -> usize {
        self.layout.len()
    }

    fn clock(&self) -> f64 {
        if self.compute_wall {
            // monotonic wall reference; only differences are used
            thread_wall_time()
        } else {
            thread_cpu_time()
        }
    }

    // -----------------------------------------------------------------
    // leader side
    // -----------------------------------------------------------------

    /// Drive one full distributed cycle at `x`. Returns `(F, ∇F)` — the
    /// *maximised* bound and its gradient; the trainer flips signs for
    /// the minimiser. On error the collectives stay in lockstep: workers
    /// park back at the command broadcast, ready for the next `eval` or
    /// `finish`.
    pub fn eval(&mut self, x: &[f64]) -> Result<(f64, Vec<f64>)> {
        let layout = &self.layout;
        let (m, q, n) = (layout.m, layout.q, layout.n);
        let c = self.chunk;
        let variational = layout.variational;
        let views = layout.views;
        let view_len = layout.view_len();
        let globals = unpack_globals(layout, x);

        // 1–3: command + parameter distribution
        let (mu_all, s_all): (Vec<f64>, Vec<f64>) = if variational {
            let mu = layout.mu_slice(x).to_vec();
            let s: Vec<f64> = layout.log_s_slice(x).iter().map(|v| v.exp()).collect();
            (mu, s)
        } else {
            (Vec::new(), Vec::new())
        };

        let comm = &mut self.comm;
        let spans = &self.spans;
        self.timer.time(Phase::Bcast, || {
            comm.bcast(0, vec![CMD_EVAL]);
            comm.bcast(0, x[..views * view_len].to_vec());
            if variational {
                for (r, span) in spans.iter().enumerate().skip(1) {
                    if let Some(sp) = span {
                        let lo = sp.start * q;
                        let hi = sp.end * q;
                        let mut msg = Vec::with_capacity(2 * (hi - lo));
                        msg.extend_from_slice(&mu_all[lo..hi]);
                        msg.extend_from_slice(&s_all[lo..hi]);
                        comm.send(r, TAG_LOCALS, &msg);
                    }
                }
            }
        });

        let (mu_span, s_span): (&[f64], &[f64]) = if variational {
            let sp = self.spans[0].expect("rank0 span");
            (&mu_all[sp.start * q..sp.end * q], &s_all[sp.start * q..sp.end * q])
        } else {
            (&[], &[])
        };

        // 4: local fwd + reduce (a trailing element counts failed ranks)
        let t0 = Instant::now();
        let c0 = self.clock();
        let fwd = self.state.local_fwd(&globals, mu_span, s_span, c, m, &self.ds);
        self.compute += self.clock() - c0;
        self.timer.add(Phase::StatsFwd, t0.elapsed());

        let swire_len = stats_wire_len(m, &self.ds);
        let wire = pack_with_flag(fwd.as_ref().ok().map(|stats| pack_stats(stats)),
                                  swire_len);
        let t0 = Instant::now();
        let reduced = self.comm.reduce_sum(0, &wire).expect("root");
        self.timer.add(Phase::Reduce, t0.elapsed());
        let fwd_fails = *reduced.last().expect("non-empty reduce");

        // 5: the indistributable core
        let t0 = Instant::now();
        let core = fwd.and_then(|_| {
            if fwd_fails > 0.0 {
                return Err(anyhow!("stats_fwd failed on {fwd_fails} rank(s)"));
            }
            let mut f_total = 0.0;
            let mut all_cts = Vec::with_capacity(self.ds.len());
            let mut direct = Vec::with_capacity(self.ds.len());
            let mut off = 0;
            for (v, &d) in self.ds.iter().enumerate() {
                let len = 4 + m * d + m * m;
                let stats = Stats::unpack(m, d, &reduced[off..off + len]);
                off += len;
                let kern = RbfArd::from_log_hyp(&globals.views[v].log_hyp);
                let out = bound_and_grads(&stats, &globals.views[v].z, &kern,
                                          globals.views[v].log_beta)?;
                f_total += out.f;
                all_cts.push(out.cts);
                direct.push((out.dz, out.dhyp, out.dlog_beta));
            }
            Ok((f_total, all_cts, direct))
        });
        self.timer.add(Phase::BoundCore, t0.elapsed());

        // 5b: cotangent broadcast — empty aborts the cycle in lockstep
        let comm = &mut self.comm;
        let (f_total, all_cts, direct) = match core {
            Ok(parts) => {
                let ds = &self.ds;
                self.timer.time(Phase::Bcast, || {
                    let mut wire = Vec::with_capacity(cts_wire_len(m, ds));
                    for cts in &parts.1 {
                        wire.extend(cts.pack());
                    }
                    comm.bcast(0, wire);
                });
                parts
            }
            Err(e) => {
                self.timer.time(Phase::Bcast, || comm.bcast(0, Vec::new()));
                return Err(e);
            }
        };

        // 6: local vjp
        let t0 = Instant::now();
        let c0 = self.clock();
        let vjp = self.state.local_vjp(&globals, &all_cts, mu_span, s_span, c, m);
        self.compute += self.clock() - c0;
        self.timer.add(Phase::StatsVjp, t0.elapsed());

        let span0_len = self.spans[0].map(|s| s.len()).unwrap_or(0) * q;
        let (view_grads, dmu_span, dls_span, vjp_err) = match vjp {
            Ok((vg, dmu, dls)) => (vg, dmu, dls, None),
            Err(e) => (Vec::new(), vec![0.0; span0_len], vec![0.0; span0_len], Some(e)),
        };

        // 7: reduce global partials + gather locals (fail flag again)
        let t0 = Instant::now();
        let gwire_len = grads_wire_len(m, q, self.ds.len());
        let gwire = pack_with_flag(vjp_err.is_none().then(|| pack_grads(&view_grads)),
                                   gwire_len);
        let greduced = self.comm.reduce_sum(0, &gwire).expect("root");

        let locals = if variational {
            let mut mine = Vec::with_capacity(dmu_span.len() * 2);
            mine.extend_from_slice(&dmu_span);
            mine.extend_from_slice(&dls_span);
            self.comm.gather(0, &mine)
        } else {
            self.comm.gather(0, &[])
        };
        self.timer.add(Phase::GatherGrads, t0.elapsed());

        if let Some(e) = vjp_err {
            return Err(e);
        }
        let vjp_fails = *greduced.last().expect("non-empty reduce");
        if vjp_fails > 0.0 {
            return Err(anyhow!("stats_vjp failed on {vjp_fails} rank(s)"));
        }

        // assemble ∇F
        let t0 = Instant::now();
        let mut grad = vec![0.0; layout.len()];
        let mut goff = 0;
        for (v, (dz_direct, dhyp_direct, dlog_beta)) in direct.iter().enumerate() {
            let o = v * view_len;
            let dz_part = &greduced[goff..goff + m * q];
            goff += m * q;
            let dhyp_part = &greduced[goff..goff + q + 1];
            goff += q + 1;
            for i in 0..q + 1 {
                grad[o + i] = dhyp_direct[i] + dhyp_part[i];
            }
            grad[o + q + 1] = *dlog_beta;
            for i in 0..m * q {
                grad[o + q + 2 + i] = dz_direct.as_slice()[i] + dz_part[i];
            }
        }
        if variational {
            let locals = locals.expect("root");
            let base_mu = views * view_len;
            let base_ls = base_mu + n * q;
            for (r, piece) in locals.iter().enumerate() {
                if let Some(sp) = self.spans[r] {
                    let len = (sp.end - sp.start) * q;
                    debug_assert_eq!(piece.len(), 2 * len);
                    grad[base_mu + sp.start * q..base_mu + sp.end * q]
                        .copy_from_slice(&piece[..len]);
                    grad[base_ls + sp.start * q..base_ls + sp.end * q]
                        .copy_from_slice(&piece[len..2 * len]);
                }
            }
        }
        self.timer.add(Phase::GatherGrads, t0.elapsed());
        self.timer.note_eval();

        Ok((f_total, grad))
    }

    /// Leader: stop the workers and collect every rank's distributable
    /// compute-seconds (indexed by rank).
    pub fn finish(&mut self) -> Vec<f64> {
        self.comm.bcast(0, vec![CMD_STOP]);
        self.comm
            .gather(0, &[self.compute])
            .expect("root")
            .into_iter()
            .map(|v| v.first().copied().unwrap_or(0.0))
            .collect()
    }

    // -----------------------------------------------------------------
    // worker side
    // -----------------------------------------------------------------

    /// Worker loop: obey broadcast commands until STOP. A compute failure
    /// is reported to the leader through the fail-count elements while
    /// the rank keeps the collectives in lockstep; the first such error
    /// is returned once the leader shuts the cluster down.
    pub fn serve(&mut self) -> Result<()> {
        let layout = &self.layout;
        let (m, q) = (layout.m, layout.q);
        let c = self.chunk;
        let variational = layout.variational;
        let rank = self.comm.rank();
        let mut sticky_err: Option<anyhow::Error> = None;

        loop {
            let cmd = self.comm.bcast(0, Vec::new());
            if cmd.is_empty() || cmd[0] == CMD_STOP {
                let _ = self.comm.gather(0, &[self.compute]);
                return match sticky_err {
                    Some(e) => Err(anyhow!("rank {rank}: {e:#}")),
                    None => Ok(()),
                };
            }
            let gx = self.comm.bcast(0, Vec::new());
            let globals = unpack_globals(layout, &pad_globals(layout, &gx));

            let (mu_span, s_span): (Vec<f64>, Vec<f64>) = if variational {
                if let Some(sp) = self.state.span {
                    let msg = self.comm.recv(0, TAG_LOCALS);
                    let len = (sp.end - sp.start) * q;
                    (msg[..len].to_vec(), msg[len..].to_vec())
                } else {
                    (Vec::new(), Vec::new())
                }
            } else {
                (Vec::new(), Vec::new())
            };

            // fwd + reduce (with fail flag)
            let c0 = self.clock();
            let fwd = self.state.local_fwd(&globals, &mu_span, &s_span, c, m, &self.ds);
            self.compute += self.clock() - c0;
            let swire_len = stats_wire_len(m, &self.ds);
            let wire = pack_with_flag(fwd.as_ref().ok().map(|stats| pack_stats(stats)),
                                      swire_len);
            let _ = self.comm.reduce_sum(0, &wire);
            if let Err(e) = &fwd {
                if sticky_err.is_none() {
                    sticky_err = Some(anyhow!("{e:#}"));
                }
            }

            // cts (empty = leader aborted the cycle)
            let cwire = self.comm.bcast(0, Vec::new());
            if cwire.is_empty() {
                continue;
            }
            let mut all_cts = Vec::with_capacity(self.ds.len());
            let mut off = 0;
            for &d in &self.ds {
                let len = 3 + m * d + m * m;
                all_cts.push(StatsCts::unpack(m, d, &cwire[off..off + len]));
                off += len;
            }

            // vjp + reduce + gather (fail flag on the reduce)
            let vjp = if fwd.is_ok() {
                let c0 = self.clock();
                let out = self.state.local_vjp(&globals, &all_cts, &mu_span, &s_span, c, m);
                self.compute += self.clock() - c0;
                out
            } else {
                Err(anyhow!("stats_fwd already failed on this rank"))
            };

            let span_len = self.state.span.map(|s| s.len()).unwrap_or(0) * q;
            let (view_grads, dmu_span, dls_span, failed) = match vjp {
                Ok((vg, dmu, dls)) => (vg, dmu, dls, false),
                Err(e) => {
                    if sticky_err.is_none() {
                        sticky_err = Some(e);
                    }
                    (Vec::new(), vec![0.0; span_len], vec![0.0; span_len], true)
                }
            };
            let gwire_len = grads_wire_len(m, q, self.ds.len());
            let gwire = pack_with_flag((!failed).then(|| pack_grads(&view_grads)),
                                       gwire_len);
            let _ = self.comm.reduce_sum(0, &gwire);

            if variational {
                let mut mine = Vec::with_capacity(dmu_span.len() * 2);
                mine.extend_from_slice(&dmu_span);
                mine.extend_from_slice(&dls_span);
                let _ = self.comm.gather(0, &mine);
            } else {
                let _ = self.comm.gather(0, &[]);
            }
        }
    }
}

/// Monotonic wall clock as seconds-since-first-use (for intra-rank
/// parallel backends, whose work the per-thread CPU clock cannot see).
fn thread_wall_time() -> f64 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}
