//! The eight-step SPMD evaluation cycle (DESIGN.md §4) as a reusable
//! component:
//!
//!   1–3. leader broadcasts command + global parameters, ships each
//!        rank its (μ, S) span            (`bcast` / tagged sends)
//!   4.   every rank: per-chunk stats_fwd (batched through the backend,
//!        fanned across threads on `parallel-cpu`) → tree `reduce_sum`
//!   5.   leader: indistributable M×M core (bound + cotangents)
//!   5b.  leader broadcasts cotangents    (`bcast`; empty = abort cycle)
//!   6.   every rank: per-chunk stats_vjp → tree `reduce_sum` of the
//!        global (Z, hyp) partials
//!   7.   `gather` of the span-local (dμ, d log S) gradients
//!   8.   (in `train`) optimiser step at the leader
//!
//! With `EngineConfig::pipeline` on (the default) steps 4–7 run as a
//! **per-view pipeline** instead of whole-cycle barriers — every rank
//! issues the same collective sequence, but compute overlaps the
//! in-flight communication:
//!
//! ```text
//!     fwd[0] ── reduce[0]
//!     for each view v:
//! L:    core[v] ── bcast cts[v] ─┐   fwd[v+1] ── reduce[v+1]
//! W:    fwd[v+1] ── reduce[v+1]  └─▸ vjp[v] ── reduce grads[v]
//!     gather (dμ, d log S)
//! ```
//!
//! so view v's `stats_vjp` starts as soon as view v's cotangents land
//! while view v+1's forward statistics are still reducing through the
//! tree, and the leader's M×M core for view v overlaps the workers'
//! fwd[v+1] compute (the cotangent broadcast itself is non-blocking).
//! Collectives use distinct FIFO tag streams, every rank issues them in
//! the same global order (fwd[0], fwd[1], grads[0], fwd[2], grads[1], …),
//! and the per-view payloads reduce element-wise over the same trees as
//! the synchronous whole-cycle wires — the pipelined objective and
//! gradient are therefore **bit-identical** to the synchronous path
//! (asserted in `rust/tests/pipeline_equiv_test.rs`).
//!
//! [`DistributedEvaluator`] owns one rank's half of that conversation:
//! the leader drives it through [`DistributedEvaluator::eval`], workers
//! sit in [`DistributedEvaluator::serve`]. Beyond EVAL and STOP, the
//! command broadcast carries two more verbs:
//!
//! - SERVE: the leader switches the whole cluster into a sharded
//!   *prediction* session
//!   ([`begin_serving`](DistributedEvaluator::begin_serving) /
//!   [`predict_sharded`](DistributedEvaluator::predict_sharded) /
//!   [`end_serving`](DistributedEvaluator::end_serving), protocol in
//!   [`super::serve`]) and back, so a freshly fitted model is served by
//!   the same ranks that trained it without leaving the SPMD world.
//! - STATS: a **stats-only pass** ([`stats_pass`](DistributedEvaluator::stats_pass)) —
//!   the leader broadcasts parameters, every rank computes its chunks'
//!   view-0 sufficient statistics through the backend batch API, and one
//!   `reduce_sum_into` tree-reduction assembles them on the leader. Each
//!   chunk's statistics occupy their **own slot** of the reduction wire
//!   (zeros elsewhere), so the reduction adds exact zeros and the
//!   leader's chunk-order fold reproduces the serial chunked
//!   construction ([`sgpr_stats_fwd_chunked`](crate::math::stats::sgpr_stats_fwd_chunked))
//!   **bit for bit at every cluster size and on either CPU backend**.
//!   This is how [`posterior_core_fresh`](DistributedEvaluator::posterior_core_fresh)
//!   builds the serving posterior with zero leader-side full-data work,
//!   and — via the serve loop's REFIT sub-command
//!   ([`refit_and_swap`](DistributedEvaluator::refit_and_swap)) — how an
//!   open serving session hot-swaps its posterior at new parameters
//!   without tearing the session down. Even that round is usually
//!   skipped at the end of a run: every successful evaluation leaves its
//!   reduced view-0 statistics **captured** on the leader, keyed by the
//!   packed parameter vector, and
//!   [`posterior_core_at`](DistributedEvaluator::posterior_core_at)
//!   reuses them when the fitted parameters match — the **free
//!   end-of-run stats** path (zero extra messages, asserted by the
//!   cluster message counters in `rust/tests/serve_test.rs`).
//!
//! Both sides keep the
//! collectives in lockstep even when a rank's compute fails mid-cycle:
//! failures ride a trailing fail-count element on each reduction, and a
//! leader-side failure aborts the cycle with an empty cotangent
//! broadcast for the failing view — in pipeline mode both sides then
//! truncate the remaining schedule identically (the leader still absorbs
//! the one fwd reduction the workers issued before they could observe
//! the abort) — so an error surfaces as an `Err` on the optimiser's next
//! step instead of a protocol desync.

use super::problem::{pad_globals, unpack_globals, GlobalParams, LatentSpec, ParamLayout,
                     Problem};
use super::frontend::{ControlOp, ServeDriver, ServingFrontend, ServingReport};
use super::serve::{DistributedPosterior, ServeSignal};
use super::train::EngineConfig;
use crate::collectives::Comm;
use crate::config::BackendKind;
use crate::coordinator::backend::{make_backends, Backend, ChunkData, ChunkTask, FwdCache,
                                  ViewParams};
use crate::coordinator::partition::{ChunkRange, Partition};
use crate::data::store::ChunkReader;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::bound::bound_and_grads;
use crate::math::predict::PosteriorCore;
use crate::math::stats::{Stats, StatsCts};
use crate::metrics::{thread_cpu_time, Phase, PhaseTimer};
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};
use std::time::Instant;

// ---------------------------------------------------------------------
// wire protocol
// ---------------------------------------------------------------------

// Command verbs and the span-scatter tag live in the cluster-wide
// registry (`collectives::protocol`), where uniqueness across
// subsystems is asserted.
use crate::collectives::protocol::{CMD_EVAL, CMD_SERVE, CMD_STATS, CMD_STOP, TAG_LOCALS};

/// What the leader's command broadcast told a worker to do next.
enum WorkerCmd {
    /// Run one evaluation cycle with these global parameters.
    Eval(GlobalParams),
    /// Enter a sharded serving session until the leader closes it.
    Serve,
    /// Contribute this rank's chunk statistics to a stats-only round.
    Stats,
    /// Shut down (report compute totals and return).
    Stop,
}

/// Wire length of one view's statistics (scalars + P + Ψ2), excluding
/// the trailing fail-count element. The single source of truth for the
/// per-view payload size — every seal/slice site goes through here.
fn view_stats_wire_len(m: usize, d: usize) -> usize {
    4 + m * d + m * m
}

/// Payload length of the whole-cycle statistics wire (all views),
/// excluding the trailing fail-count element.
fn stats_wire_len(m: usize, ds: &[usize]) -> usize {
    ds.iter().map(|&d| view_stats_wire_len(m, d)).sum()
}

/// Payload length of the global-gradient partials (dZ + dhyp per view),
/// excluding the trailing fail-count element.
fn grads_wire_len(m: usize, q: usize, views: usize) -> usize {
    views * (m * q + q + 1)
}

/// Finish a wire buffer built in place: append the fail flag reducers
/// sum into a fail count (`0.0` from a rank whose compute succeeded; on
/// failure the payload is replaced by zeros and flagged `1.0`). Both
/// sides of the protocol — leader `eval` and worker `serve` — seal
/// through this one helper so the wire format cannot drift between them.
// lint: no-alloc
fn seal_wire(wire: &mut Vec<f64>, ok: bool, len: usize) {
    if ok {
        debug_assert_eq!(wire.len(), len, "wire payload length");
        wire.push(0.0);
    } else {
        wire.clear();
        wire.resize(len + 1, 0.0);
        wire[len] = 1.0;
    }
}

// ---------------------------------------------------------------------
// reusable hot-path buffers
// ---------------------------------------------------------------------

/// Everything the evaluation hot path reuses cycle to cycle so the
/// pack/reduce/unpack round-trips stop allocating: wire buffers for the
/// three collectives, span-local gradient accumulators, the leader's
/// (μ, S) expansions, per-chunk (μ, S) slices shared by every view's
/// fwd and vjp batches, and the per-view fwd→vjp caches. Reuse only
/// saves the allocations — every buffer is (re)written before it is
/// read, so the values match a freshly-allocated cycle bit for bit.
#[derive(Default)]
struct CycleScratch {
    /// Wire for the fwd-stats reduction(s); reduced in place.
    stats_wire: Vec<f64>,
    /// Wire for the grads reduction(s); reduced in place.
    grads_wire: Vec<f64>,
    /// Leader-side cotangent broadcast buffer (round-trips through
    /// `bcast`, which hands the root its vector back).
    cts_wire: Vec<f64>,
    /// Leader-side μ and S = exp(log S) expansions of the parameter
    /// vector.
    mu_all: Vec<f64>,
    s_all: Vec<f64>,
    /// Span-local gradient accumulators (dμ, d log S), zeroed per cycle.
    dmu_span: Vec<f64>,
    dls_span: Vec<f64>,
    /// Gather payload (dμ ++ d log S).
    locals: Vec<f64>,
    /// Per-chunk (μ, S) slices. Live rows are refreshed in place each
    /// cycle; the padding rows were set once at construction (μ = 0,
    /// S = 1) and are never dirtied.
    latents: Vec<(Mat, Mat)>,
    /// Per-view per-chunk fwd→vjp caches from the latest forward pass.
    caches: Vec<Vec<FwdCache>>,
    /// Leader: per-view reduced statistics, unpacked in place.
    view_stats: Vec<Stats>,
    /// Workers: per-view cotangents, unpacked in place.
    view_cts: Vec<StatsCts>,
}

/// Refresh the per-chunk (μ, S) slices from the rank's span-local
/// buffers (`mu_span`/`s_span` are the span's rows × Q, row-major).
// lint: no-alloc
fn refresh_latents(latents: &mut [(Mat, Mat)], chunks: &[ChunkData], span_start: usize,
                   q: usize, mu_span: &[f64], s_span: &[f64]) {
    for ((mu, s), chunk) in latents.iter_mut().zip(chunks) {
        let off = (chunk.start - span_start) * q;
        let live = chunk.live * q;
        mu.as_mut_slice()[..live].copy_from_slice(&mu_span[off..off + live]);
        s.as_mut_slice()[..live].copy_from_slice(&s_span[off..off + live]);
    }
}

// ---------------------------------------------------------------------
// per-rank worker state
// ---------------------------------------------------------------------

/// Per-rank state: resident chunks (one fully-assembled `ChunkData` per
/// view per chunk — mask, supervised x and the view's Y tile attached at
/// build time, so nothing static is copied on the evaluation hot path)
/// and a backend per view.
///
/// Store-backed problems (`LatentSpec::ObservedStore`) run in **streamed
/// mode** instead: `view_chunks[0]` holds only zero-size skeletons (the
/// `start`/`live` grid the STATS slot mapping and the chunk-order folds
/// key off), and the chunk payloads are pulled through `stream` — a
/// double-buffered pair of padded `ChunkData` slots fed by the store's
/// [`ChunkReader`] — in windows of two, so the rank's working set is
/// O(chunk) instead of O(N/P). The per-chunk math and the chunk-order
/// folds are unchanged, so streamed trajectories are bit-identical to
/// resident ones.
struct WorkerState {
    /// `view_chunks[v][c]` — chunk c's data for view v (skeletons only
    /// in streamed mode).
    view_chunks: Vec<Vec<ChunkData>>,
    backends: Vec<Box<dyn Backend>>,
    /// Runtime kept alive for the XLA backends (owns the PJRT client).
    _runtime: Option<Runtime>,
    span: Option<ChunkRange>,
    q: usize,
    variational: bool,
    /// Streamed mode: the rank's chunk reader + double-buffered slots.
    stream: Option<ChunkStream>,
}

/// A rank's streaming window over its store chunks: a reader plus two
/// reusable padded `ChunkData` slots. Manifest chunk `k` always lands in
/// slot `k % 2`, so the two chunks of a window (consecutive ids) never
/// collide.
struct ChunkStream {
    reader: Box<dyn ChunkReader>,
    /// Fixed chunk size C (= the store's `chunk_rows`); maps a chunk's
    /// `start` back to its manifest id.
    chunk_rows: usize,
    slots: [ChunkData; 2],
}

impl ChunkStream {
    /// Read the chunk starting at `start` (`live` rows) into its slot:
    /// payload rows first, then zeroed padding and the {0,1} mask. The
    /// reader applies centering and verifies the chunk checksum.
    // lint: no-alloc
    fn fill(&mut self, start: usize, live: usize) -> Result<()> {
        let k = start / self.chunk_rows;
        let slot = &mut self.slots[k % 2];
        slot.start = start;
        slot.live = live;
        let q = slot.x.cols();
        let d = slot.y.cols();
        let x = slot.x.as_mut_slice();
        let y = slot.y.as_mut_slice();
        self.reader.read_chunk(k, x, y)?;
        // a short (tail) chunk may reuse a slot a full chunk dirtied
        x[live * q..].fill(0.0);
        y[live * d..].fill(0.0);
        slot.w[..live].fill(1.0);
        slot.w[live..].fill(0.0);
        Ok(())
    }

    /// The slot holding the chunk that starts at `start`.
    fn slot(&self, start: usize) -> &ChunkData {
        &self.slots[(start / self.chunk_rows) % 2]
    }
}

/// Assemble one view's batch: each resident chunk (borrowed) with its
/// (μ, S) slice attached for variational problems — borrowed from the
/// evaluator's reusable per-chunk buffers, not allocated per call.
fn view_tasks<'a>(chunks: &'a [ChunkData], latents: &'a [(Mat, Mat)],
                  variational: bool) -> Vec<ChunkTask<'a>> {
    chunks
        .iter()
        .enumerate()
        .map(|(i, chunk)| ChunkTask {
            chunk,
            latent: if variational {
                Some((&latents[i].0, &latents[i].1))
            } else {
                None
            },
        })
        .collect()
}

impl WorkerState {
    fn build(problem: &Problem, cfg: &EngineConfig, part: &Partition, rank: usize)
             -> Result<WorkerState> {
        let q = problem.q;
        let c = part.chunk;
        let ranges = &part.per_worker[rank];
        let variational = problem.latent.is_variational();
        let streamed = matches!(problem.latent, LatentSpec::ObservedStore);

        // chunk skeletons (mask + supervised x); in streamed mode they
        // carry only the start/live grid — payloads stay on disk and the
        // mask lives in the stream slots, so a rank's static state is
        // O(#chunks), not O(N/P)
        let mut skeletons = Vec::with_capacity(ranges.len());
        for r in ranges {
            let live = r.len();
            let w = if streamed {
                Vec::new()
            } else {
                let mut w = vec![0.0; c];
                w[..live].fill(1.0);
                w
            };
            let x = match &problem.latent {
                LatentSpec::Observed(x_all) => {
                    let mut x = Mat::zeros(c, q);
                    for i in 0..live {
                        x.row_mut(i).copy_from_slice(x_all.row(r.start + i));
                    }
                    x
                }
                LatentSpec::ObservedStore | LatentSpec::Variational { .. } => {
                    Mat::zeros(0, 0)
                }
            };
            skeletons.push(ChunkData { start: r.start, live, y: Mat::zeros(0, 0), x, w });
        }

        // per-view resident chunks: skeleton + the view's padded Y tile
        // (streamed mode keeps the bare skeletons — validation pinned it
        // to a single store-backed view)
        let mut view_chunks = Vec::with_capacity(problem.views.len());
        if streamed {
            view_chunks.push(skeletons);
        } else {
            for view in &problem.views {
                let y_all = view.y.resident()
                    .ok_or_else(|| anyhow!("resident problem with store view"))?;
                let d = y_all.cols();
                let mut chunks = Vec::with_capacity(ranges.len());
                for (r, skel) in ranges.iter().zip(&skeletons) {
                    let mut y = Mat::zeros(c, d);
                    for i in 0..r.len() {
                        y.row_mut(i).copy_from_slice(y_all.row(r.start + i));
                    }
                    let mut chunk = skel.clone();
                    chunk.y = y;
                    chunks.push(chunk);
                }
                view_chunks.push(chunks);
            }
        }

        // streamed mode: open this rank's reader and preallocate the
        // double-buffered slots
        let stream = if streamed {
            let src = problem.views[0].y.store()
                .ok_or_else(|| anyhow!("ObservedStore problem without a store"))?;
            let man = src.manifest();
            if man.chunk_rows != c {
                return Err(anyhow!(
                    "store chunk_rows {} != partition chunk {c}: the store's \
                     grid must drive the partition (Partition::from_manifest)",
                    man.chunk_rows));
            }
            let mk_slot = || ChunkData {
                start: 0,
                live: 0,
                y: Mat::zeros(c, man.d),
                x: Mat::zeros(c, man.q),
                w: vec![0.0; c],
            };
            Some(ChunkStream {
                reader: src.open_reader()?,
                chunk_rows: c,
                slots: [mk_slot(), mk_slot()],
            })
        } else {
            None
        };

        // backends, via the kind-keyed factory
        let aot_configs: Vec<String> =
            problem.views.iter().map(|v| v.aot_config.clone()).collect();
        let (backends, runtime) =
            make_backends(cfg.backend, &aot_configs, &cfg.artifacts_dir)?;

        Ok(WorkerState {
            view_chunks,
            backends,
            _runtime: runtime,
            span: part.worker_span(rank),
            q,
            variational,
            stream,
        })
    }

    /// The rank's span start when (μ, S) slices must be attached.
    fn latent_start(&self) -> Option<usize> {
        if self.variational {
            self.span.map(|s| s.start)
        } else {
            None
        }
    }

    /// View 0's **per-chunk** forward statistics at the given parameters
    /// — the stats-only pass. Supervised chunks only (no latents, KL
    /// off); results come back in chunk order regardless of how the
    /// backend parallelised them, which is what lets the leader fold
    /// them into the serial chunk-order construction.
    fn fwd_view0_per_chunk(&mut self, gv: &super::problem::GlobalView)
                           -> Result<Vec<Stats>> {
        if self.stream.is_some() {
            return self.fwd_view0_per_chunk_streamed(gv);
        }
        let tasks = view_tasks(&self.view_chunks[0], &[], false);
        let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
        let (stats, _caches) = self.backends[0].stats_fwd_batch(&tasks, &vp, false)?;
        Ok(stats)
    }

    /// Streamed-mode stats-only pass: pull the rank's chunks through the
    /// double-buffered window and batch each window through the backend.
    /// Per-chunk stats are independent of batching, so the collected
    /// chunk-order list is bit-identical to the resident whole-list
    /// batch.
    fn fwd_view0_per_chunk_streamed(&mut self, gv: &super::problem::GlobalView)
                                    -> Result<Vec<Stats>> {
        let stream = self.stream.as_mut()
            .ok_or_else(|| anyhow!("streamed call without a stream"))?;
        let chunks = &self.view_chunks[0];
        let backend = &mut self.backends[0];
        let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
        let mut out = Vec::with_capacity(chunks.len());
        let mut i = 0;
        while i < chunks.len() {
            let hi = (i + 2).min(chunks.len());
            for ch in &chunks[i..hi] {
                stream.fill(ch.start, ch.live)?;
            }
            let tasks: Vec<ChunkTask> = chunks[i..hi]
                .iter()
                .map(|ch| ChunkTask { chunk: stream.slot(ch.start), latent: None })
                .collect();
            let (stats, _caches) = backend.stats_fwd_batch(&tasks, &vp, false)?;
            out.extend(stats);
            i = hi;
        }
        Ok(out)
    }

    /// One view's local forward pass: per-chunk stats summed over chunks
    /// (in chunk order, regardless of how the backend parallelised them)
    /// plus the per-chunk fwd→vjp caches. `d` is the view's global
    /// output width: ranks with zero chunks must still produce stats of
    /// the global shape for the reducer.
    fn fwd_view(&mut self, v: usize, gv: &super::problem::GlobalView,
                latents: &[(Mat, Mat)], m: usize, d: usize)
                -> Result<(Stats, Vec<FwdCache>)> {
        if self.stream.is_some() {
            return self.fwd_view_streamed(v, gv, m, d);
        }
        let tasks = view_tasks(&self.view_chunks[v], latents, self.variational);
        let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
        // KL is counted exactly once: attached to view 0.
        let include_kl = self.variational && v == 0;
        let (stats, caches) = self.backends[v].stats_fwd_batch(&tasks, &vp, include_kl)?;
        // first chunk's stats become the accumulator — the zero-filled
        // M×D/M×M matrices are only materialised on chunkless ranks
        let mut it = stats.into_iter();
        let mut acc = match it.next() {
            Some(st) => st,
            None => Stats::zeros(m, d),
        };
        for st in it {
            acc.add_assign(&st);
        }
        Ok((acc, caches))
    }

    /// Streamed-mode forward: windows of two chunks through the stream
    /// slots, folded first-chunk-as-accumulator in chunk order — the
    /// same per-chunk math and fold order as the resident whole-list
    /// batch, hence bit-identical. No caches are retained (they would be
    /// O(N/P·M)); the VJP recomputes, which the backends' cache contract
    /// guarantees is bit-identical (`caches.get(i) → None → recompute`).
    fn fwd_view_streamed(&mut self, v: usize, gv: &super::problem::GlobalView,
                         m: usize, d: usize) -> Result<(Stats, Vec<FwdCache>)> {
        let stream = self.stream.as_mut()
            .ok_or_else(|| anyhow!("streamed call without a stream"))?;
        let chunks = &self.view_chunks[0];
        let backend = &mut self.backends[v];
        let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
        let mut acc: Option<Stats> = None;
        let mut i = 0;
        while i < chunks.len() {
            let hi = (i + 2).min(chunks.len());
            for ch in &chunks[i..hi] {
                stream.fill(ch.start, ch.live)?;
            }
            let tasks: Vec<ChunkTask> = chunks[i..hi]
                .iter()
                .map(|ch| ChunkTask { chunk: stream.slot(ch.start), latent: None })
                .collect();
            let (stats, _caches) = backend.stats_fwd_batch(&tasks, &vp, false)?;
            for st in stats {
                match &mut acc {
                    None => acc = Some(st),
                    Some(a) => a.add_assign(&st),
                }
            }
            i = hi;
        }
        Ok((acc.unwrap_or_else(|| Stats::zeros(m, d)), Vec::new()))
    }

    /// One view's local VJP pass, reusing the view's fwd caches.
    /// Accumulates the span-local (dμ, d log S) into the provided
    /// buffers and returns the view's global (dZ, dhyp) partials.
    #[allow(clippy::too_many_arguments)]
    fn vjp_view(&mut self, v: usize, gv: &super::problem::GlobalView, cts: &StatsCts,
                latents: &[(Mat, Mat)], caches: &[FwdCache],
                dmu_span: &mut [f64], dls_span: &mut [f64], m: usize)
                -> Result<(Mat, Vec<f64>)> {
        if self.stream.is_some() {
            return self.vjp_view_streamed(v, gv, cts, m);
        }
        let tasks = view_tasks(&self.view_chunks[v], latents, self.variational);
        let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
        let grads = self.backends[v].stats_vjp_batch(&tasks, &vp, cts, caches)?;

        let latent_start = self.latent_start();
        let mut dz = Mat::zeros(m, self.q);
        let mut dhyp = vec![0.0; self.q + 1];
        for (task, g) in tasks.iter().zip(&grads) {
            if let Some(span_start) = latent_start {
                // accumulate local grads (chain dS -> dlogS needs S)
                let (_, s) = task.latent()
                    .ok_or_else(|| anyhow!("variational task without latent"))?;
                let off = (task.chunk.start - span_start) * self.q;
                for i in 0..task.chunk.live * self.q {
                    dmu_span[off + i] += g.dmu.as_slice()[i];
                    dls_span[off + i] += g.ds.as_slice()[i] * s.as_slice()[i];
                }
            }
            dz.axpy(1.0, &g.dz);
            for (a, b) in dhyp.iter_mut().zip(&g.dhyp) {
                *a += b;
            }
        }
        Ok((dz, dhyp))
    }

    /// Streamed-mode VJP: the same chunk windows as the forward, with
    /// empty caches (the backends recompute, bit-identically) and the
    /// (dZ, dhyp) partials accumulated in chunk order — never
    /// variational, so there are no span-local latent gradients.
    fn vjp_view_streamed(&mut self, v: usize, gv: &super::problem::GlobalView,
                         cts: &StatsCts, m: usize) -> Result<(Mat, Vec<f64>)> {
        let stream = self.stream.as_mut()
            .ok_or_else(|| anyhow!("streamed call without a stream"))?;
        let chunks = &self.view_chunks[0];
        let backend = &mut self.backends[v];
        let vp = ViewParams { z: &gv.z, log_hyp: &gv.log_hyp };
        let mut dz = Mat::zeros(m, self.q);
        let mut dhyp = vec![0.0; self.q + 1];
        let mut i = 0;
        while i < chunks.len() {
            let hi = (i + 2).min(chunks.len());
            for ch in &chunks[i..hi] {
                stream.fill(ch.start, ch.live)?;
            }
            let tasks: Vec<ChunkTask> = chunks[i..hi]
                .iter()
                .map(|ch| ChunkTask { chunk: stream.slot(ch.start), latent: None })
                .collect();
            let grads = backend.stats_vjp_batch(&tasks, &vp, cts, &[])?;
            for g in &grads {
                dz.axpy(1.0, &g.dz);
                for (a, b) in dhyp.iter_mut().zip(&g.dhyp) {
                    *a += b;
                }
            }
            i = hi;
        }
        Ok((dz, dhyp))
    }
}

// ---------------------------------------------------------------------
// the evaluator
// ---------------------------------------------------------------------

/// One rank's half of the distributed evaluation cycle. Rank 0 (the
/// leader) calls [`eval`](DistributedEvaluator::eval) once per objective
/// evaluation and [`finish`](DistributedEvaluator::finish) when done;
/// every other rank parks in [`serve`](DistributedEvaluator::serve).
pub struct DistributedEvaluator {
    comm: Comm,
    state: WorkerState,
    layout: ParamLayout,
    /// Output width per view (global, identical on every rank).
    ds: Vec<usize>,
    /// Every rank's datapoint span (for scattering (μ,S) and gathering
    /// their gradients).
    spans: Vec<Option<ChunkRange>>,
    /// Fixed chunk size C (slot indexing for the stats-only pass:
    /// global chunk index = chunk.start / C).
    chunk_rows: usize,
    /// Total chunk count K across the cluster (sizes the STATS wire).
    num_chunks: usize,
    timer: PhaseTimer,
    /// Distributable compute consumed by this rank (seconds).
    compute: f64,
    /// Measure compute as wall-clock (intra-rank fan-out spreads the work
    /// over threads the rank-thread CPU clock cannot see) vs thread CPU
    /// time (serial backends on a time-shared host).
    compute_wall: bool,
    /// Per-view pipelined schedule vs the whole-cycle synchronous one.
    /// SPMD: every rank of a cluster shares one `EngineConfig`, so the
    /// two sides always agree.
    pipeline: bool,
    /// Reusable hot-path buffers (taken out for the duration of each
    /// `eval`/`serve` call so `self` stays freely borrowable).
    scratch: CycleScratch,
    /// Leader-side serving session, when one is open
    /// ([`begin_serving`](DistributedEvaluator::begin_serving)).
    sharded: Option<DistributedPosterior>,
    /// Free end-of-run stats: the packed parameter vector of the most
    /// recent successful evaluation (leader-side, supervised problems
    /// only) — the key the capture below is valid for.
    captured_x: Vec<f64>,
    /// The reduced view-0 [`Stats`] of that evaluation, in wire form
    /// (reused buffer; unpacked only on a capture hit).
    captured_stats: Vec<f64>,
    /// Whether the capture pair above holds a live evaluation.
    captured: bool,
}

impl DistributedEvaluator {
    /// Build this rank's state (chunks, tiles, backends) and bind it to
    /// the communicator.
    pub fn new(problem: &Problem, cfg: &EngineConfig, part: &Partition, comm: Comm)
               -> Result<DistributedEvaluator> {
        let rank = comm.rank();
        let state = WorkerState::build(problem, cfg, part, rank)?;
        let layout = ParamLayout::new(problem);
        let ds: Vec<usize> = problem.views.iter().map(|v| v.y.cols()).collect();
        let spans = (0..part.workers()).map(|r| part.worker_span(r)).collect();
        let compute_wall = matches!(cfg.backend, BackendKind::ParallelCpu { .. });
        let scratch = CycleScratch {
            latents: if problem.latent.is_variational() {
                state.view_chunks[0]
                    .iter()
                    .map(|_| {
                        (Mat::zeros(cfg.chunk, problem.q),
                         Mat::from_vec(cfg.chunk, problem.q,
                                       vec![1.0; cfg.chunk * problem.q]))
                    })
                    .collect()
            } else {
                Vec::new()
            },
            caches: vec![Vec::new(); ds.len()],
            view_stats: ds.iter().map(|&d| Stats::zeros(layout.m, d)).collect(),
            view_cts: ds.iter().map(|&d| StatsCts::zeros(layout.m, d)).collect(),
            ..CycleScratch::default()
        };
        Ok(DistributedEvaluator {
            comm,
            state,
            layout,
            ds,
            spans,
            chunk_rows: part.chunk,
            num_chunks: part.num_chunks(),
            timer: PhaseTimer::new(),
            compute: 0.0,
            compute_wall,
            pipeline: cfg.pipeline,
            scratch,
            sharded: None,
            captured_x: Vec::new(),
            captured_stats: Vec::new(),
            captured: false,
        })
    }

    /// This rank's index (0 = leader).
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// Phase timings accumulated on this rank.
    pub fn timer(&self) -> &PhaseTimer {
        &self.timer
    }

    /// Cluster-wide bytes shipped so far (shared counter).
    pub fn bytes_sent(&self) -> u64 {
        self.comm.bytes_sent()
    }

    /// Cluster-wide message count so far (shared counter).
    pub fn messages_sent(&self) -> u64 {
        self.comm.messages_sent()
    }

    /// Protocol messages **this rank** has sent so far (the chaos
    /// harness's fault-index space; see `testutil::chaos`).
    pub fn local_messages_sent(&self) -> u64 {
        self.comm.local_messages_sent()
    }

    /// Number of optimisable parameters.
    pub fn n_params(&self) -> usize {
        self.layout.len()
    }

    fn clock(&self) -> f64 {
        if self.compute_wall {
            // monotonic wall reference; only differences are used
            thread_wall_time()
        } else {
            thread_cpu_time()
        }
    }

    // -----------------------------------------------------------------
    // shared per-cycle pieces
    // -----------------------------------------------------------------

    /// Step 4 for one view (pipeline mode): compute the local forward
    /// batch (skipped once an earlier view failed on this rank — the
    /// first error wins and the leader aborts at the first flagged view
    /// anyway), seal the fail-flagged wire, and run the view's reduction
    /// in place. Returns the cluster-wide fail count on the root; the
    /// return value is meaningless elsewhere. `Err` means the transport
    /// itself failed (dead peer) — terminal for this rank.
    fn fwd_reduce_view(&mut self, v: usize, globals: &GlobalParams,
                       scratch: &mut CycleScratch,
                       err: &mut Option<anyhow::Error>) -> Result<f64> {
        let m = self.layout.m;
        let wire_len = view_stats_wire_len(m, self.ds[v]);
        let t0 = Instant::now();
        let c0 = self.clock();
        scratch.stats_wire.clear();
        let ok = if err.is_none() {
            match self.state.fwd_view(v, &globals.views[v], &scratch.latents, m,
                                      self.ds[v]) {
                Ok((st, caches)) => {
                    scratch.caches[v] = caches;
                    st.pack_into(&mut scratch.stats_wire);
                    true
                }
                Err(e) => {
                    *err = Some(e);
                    false
                }
            }
        } else {
            false
        };
        self.compute += self.clock() - c0;
        self.timer.add(Phase::StatsFwd, t0.elapsed());

        seal_wire(&mut scratch.stats_wire, ok, wire_len);
        let t0 = Instant::now();
        let res = self.comm.reduce_sum_into(0, &mut scratch.stats_wire);
        self.timer.add(Phase::Reduce, t0.elapsed());
        res?;
        scratch.stats_wire.last().copied()
            .ok_or_else(|| anyhow!("empty stats reduce wire"))
    }

    /// Step 6/7a for one view (pipeline mode): compute the view's VJP
    /// (skipped after an earlier failure on this rank), seal and reduce
    /// its fail-flagged grads wire in place. Returns whether this rank's
    /// vjp ran; `Err` is a terminal transport failure.
    #[allow(clippy::too_many_arguments)]
    fn vjp_reduce_view(&mut self, v: usize, globals: &GlobalParams, cts: &StatsCts,
                       scratch: &mut CycleScratch, skip: bool,
                       err: &mut Option<anyhow::Error>) -> Result<bool> {
        let (m, q) = (self.layout.m, self.layout.q);
        let t0 = Instant::now();
        let c0 = self.clock();
        scratch.grads_wire.clear();
        let ok = if skip || err.is_some() {
            false
        } else {
            match self.state.vjp_view(v, &globals.views[v], cts, &scratch.latents,
                                      &scratch.caches[v], &mut scratch.dmu_span,
                                      &mut scratch.dls_span, m) {
                Ok((dz, dhyp)) => {
                    scratch.grads_wire.extend_from_slice(dz.as_slice());
                    scratch.grads_wire.extend_from_slice(&dhyp);
                    true
                }
                Err(e) => {
                    *err = Some(e);
                    false
                }
            }
        };
        self.compute += self.clock() - c0;
        self.timer.add(Phase::StatsVjp, t0.elapsed());

        seal_wire(&mut scratch.grads_wire, ok, m * q + q + 1);
        let t0 = Instant::now();
        let res = self.comm.reduce_sum_into(0, &mut scratch.grads_wire);
        self.timer.add(Phase::GatherGrads, t0.elapsed());
        res?;
        Ok(ok)
    }

    /// Step 7b: gather the span-local gradients (zeroed first if this
    /// rank's vjp failed, matching the synchronous protocol).
    // lint: no-alloc
    fn gather_locals(&mut self, scratch: &mut CycleScratch, vjp_ok: bool)
                     -> Result<Option<Vec<Vec<f64>>>> {
        if self.layout.variational {
            if !vjp_ok {
                for v in scratch.dmu_span.iter_mut() {
                    *v = 0.0;
                }
                for v in scratch.dls_span.iter_mut() {
                    *v = 0.0;
                }
            }
            scratch.locals.clear();
            scratch.locals.extend_from_slice(&scratch.dmu_span);
            scratch.locals.extend_from_slice(&scratch.dls_span);
            self.comm.gather(0, &scratch.locals)
        } else {
            self.comm.gather(0, &[])
        }
    }

    /// Zero the span-local accumulators for a fresh cycle.
    // lint: no-alloc
    fn reset_span_grads(&self, scratch: &mut CycleScratch) {
        let span_len = self.state.span.map(|s| s.len()).unwrap_or(0) * self.layout.q;
        scratch.dmu_span.clear();
        scratch.dmu_span.resize(span_len, 0.0);
        scratch.dls_span.clear();
        scratch.dls_span.resize(span_len, 0.0);
    }

    // -----------------------------------------------------------------
    // the stats-only round (both sides)
    // -----------------------------------------------------------------

    /// One rank's half of the stats-only collective (run by every rank
    /// after the verb + parameter broadcasts): compute this rank's
    /// view-0 chunk statistics, pack **each chunk into its own
    /// global-chunk slot** of the K-slot wire (zeros everywhere else),
    /// and tree-reduce in place. Every slot has exactly one non-zero
    /// contributor, so the reduction only ever adds zeros — exact in
    /// IEEE arithmetic — and the reduced wire is independent of the
    /// cluster size and reduction topology. Failures ride the trailing
    /// fail-count element exactly like the training reductions.
    ///
    /// The slot wire is K× larger than the training reduction's
    /// (deliberate: it buys the rank-count-invariant fold through the
    /// same `reduce_sum_into` collective the rest of the cycle uses,
    /// and a refit runs once per posterior rebuild, not per optimiser
    /// step). If huge-K refits ever become hot, a rank-order `gather`
    /// of each rank's *owned* slots would ship every slot exactly once
    /// while preserving the identical chunk-order fold (see ROADMAP).
    ///
    /// Returns the cluster-wide fail count on the root (meaningless
    /// elsewhere) plus this rank's local error, if any; the outer `Err`
    /// is a terminal transport failure.
    fn stats_round(&mut self, globals: &GlobalParams, scratch: &mut CycleScratch)
                   -> Result<(f64, Option<anyhow::Error>)> {
        let slot = view_stats_wire_len(self.layout.m, self.ds[0]);
        let wire_len = self.num_chunks * slot;

        let t0 = Instant::now();
        let c0 = self.clock();
        scratch.stats_wire.clear();
        scratch.stats_wire.resize(wire_len, 0.0);
        let mut err: Option<anyhow::Error> = None;
        if self.state.variational {
            // defensive: the leader refuses STATS for variational
            // problems before any broadcast, so this only fires if a
            // mixed-problem cluster ever desyncs — flag, stay lockstep
            err = Some(anyhow!("stats pass needs a supervised problem"));
        } else {
            match self.state.fwd_view0_per_chunk(&globals.views[0]) {
                Ok(stats) => {
                    let mut packed = Vec::with_capacity(slot);
                    for (chunk, st) in self.state.view_chunks[0].iter().zip(&stats) {
                        let k = chunk.start / self.chunk_rows;
                        packed.clear();
                        st.pack_into(&mut packed);
                        scratch.stats_wire[k * slot..(k + 1) * slot]
                            .copy_from_slice(&packed);
                    }
                }
                Err(e) => err = Some(e),
            }
        }
        self.compute += self.clock() - c0;
        self.timer.add(Phase::StatsFwd, t0.elapsed());

        seal_wire(&mut scratch.stats_wire, err.is_none(), wire_len);
        let t0 = Instant::now();
        let res = self.comm.reduce_sum_into(0, &mut scratch.stats_wire);
        self.timer.add(Phase::Reduce, t0.elapsed());
        res?;
        let fails = scratch.stats_wire.last().copied()
            .ok_or_else(|| anyhow!("empty stats reduce wire"))?;
        Ok((fails, err))
    }

    /// Leader half of the stats collective, after the verb broadcast:
    /// parameter broadcast, this rank's own chunk contributions, the
    /// tree reduction, and the chunk-order fold of the reduced slots.
    fn stats_collective(&mut self, x: &[f64], scratch: &mut CycleScratch)
                        -> Result<Stats> {
        let gx = x[..self.layout.global_len()].to_vec();
        {
            let comm = &mut self.comm;
            self.timer.time(Phase::Bcast, || comm.bcast(0, gx))?;
        }
        let globals = unpack_globals(&self.layout,
                                     &pad_globals(&self.layout,
                                                  &x[..self.layout.global_len()]));

        let (fails, err) = self.stats_round(&globals, scratch)?;
        if let Some(e) = err {
            return Err(e);
        }
        if fails > 0.0 {
            return Err(anyhow!("stats pass failed on {fails} rank(s)"));
        }

        // fold the per-chunk slots in global chunk order — the serial
        // summation discipline, independent of the cluster size
        let (m, d) = (self.layout.m, self.ds[0]);
        let slot = view_stats_wire_len(m, d);
        let mut acc = Stats::zeros(m, d);
        let mut st = Stats::zeros(m, d);
        for k in 0..self.num_chunks {
            st.unpack_from(&scratch.stats_wire[k * slot..(k + 1) * slot]);
            acc.add_assign(&st);
        }
        Ok(acc)
    }

    /// Leader: run a distributed **stats-only pass** (the STATS verb) at
    /// the packed parameter vector `x`: every rank contributes its
    /// chunks' view-0 sufficient statistics and the leader receives the
    /// global [`Stats`] — bit-identical to the serial chunked
    /// construction [`sgpr_stats_fwd_chunked`](crate::math::stats::sgpr_stats_fwd_chunked)
    /// at the engine's chunk size, for every cluster size and CPU
    /// backend. Supervised (observed-X) problems only.
    pub fn stats_pass(&mut self, x: &[f64]) -> Result<Stats> {
        if self.sharded.is_some() {
            return Err(anyhow!(
                "a serving session is open: use refit_and_swap or end_serving first"));
        }
        if self.layout.variational {
            return Err(anyhow!("stats pass needs a supervised problem (observed X)"));
        }
        {
            let comm = &mut self.comm;
            self.timer.time(Phase::Bcast, || comm.bcast(0, vec![CMD_STATS]))?;
        }
        let mut scratch = std::mem::take(&mut self.scratch);
        let out = self.stats_collective(x, &mut scratch);
        self.scratch = scratch;
        out
    }

    /// Leader: rebuild of the serving posterior at `x`. When `x` is
    /// exactly the parameter vector of the most recent successful
    /// evaluation, the statistics that evaluation already reduced are
    /// reused — the **free end-of-run stats** path: no broadcast, no
    /// reduction, zero messages (asserted via the cluster message
    /// counters in `rust/tests/serve_test.rs`); the optimiser's final
    /// accepted evaluation makes `train_then_predict`'s posterior build
    /// free. Otherwise one distributed stats-only pass runs
    /// ([`posterior_core_fresh`](DistributedEvaluator::posterior_core_fresh)).
    ///
    /// The captured statistics come off the training reduction (rank
    /// partials summed over the tree), the fresh pass off the slot wire
    /// (global chunk-order fold) — identical up to float summation
    /// order, so the two cores may differ in the last ulp. Code that
    /// needs the slot-wire bits exactly (the hot-swap demo, which
    /// asserts a refit at the same parameters changes nothing) should
    /// call `posterior_core_fresh` directly.
    pub fn posterior_core_at(&mut self, x: &[f64]) -> Result<PosteriorCore> {
        if self.captured && self.captured_x.as_slice() == x {
            let mut stats = Stats::zeros(self.layout.m, self.ds[0]);
            stats.unpack_from(&self.captured_stats);
            return self.core_from_stats(x, &stats);
        }
        self.posterior_core_fresh(x)
    }

    /// Leader: distributed rebuild of the serving posterior at `x` — a
    /// stats-only pass followed by the M×M factorisations
    /// ([`PosteriorCore::new`]) on the reduced statistics, always
    /// running the collective round (never the final-eval capture). The
    /// leader does **no full-data work**: its own contribution is its
    /// resident chunks, like any other rank.
    pub fn posterior_core_fresh(&mut self, x: &[f64]) -> Result<PosteriorCore> {
        let stats = self.stats_pass(x)?;
        self.core_from_stats(x, &stats)
    }

    /// The posterior core implied by parameters `x` and reduced
    /// statistics: view 0's kernel/Z/β exactly as `unpack_fitted` would
    /// produce them, so the core is bit-identical to one built from the
    /// trainer's `Fitted` at the same `x`.
    fn core_from_stats(&self, x: &[f64], stats: &Stats) -> Result<PosteriorCore> {
        let globals = unpack_globals(&self.layout,
                                     &pad_globals(&self.layout,
                                                  &x[..self.layout.global_len()]));
        let gv = &globals.views[0];
        PosteriorCore::new(RbfArd::from_log_hyp(&gv.log_hyp), gv.z.clone(),
                           gv.log_beta.exp(), stats)
    }

    /// Leader: **posterior hot-swap** — with a serving session open, run
    /// a stats-only round at the (new) parameters `x` and re-broadcast
    /// the rebuilt core, without tearing the session down: workers leave
    /// the serve loop for exactly one stats round and resume serving.
    ///
    /// Failure is atomic: if any rank's stats computation or the
    /// leader's factorisation fails, no swap broadcast goes out and the
    /// session keeps serving the old posterior (every rank is back at
    /// the serve sub-command broadcast either way).
    pub fn refit_and_swap(&mut self, x: &[f64]) -> Result<()> {
        if self.layout.variational {
            return Err(anyhow!("stats pass needs a supervised problem (observed X)"));
        }
        let Some(mut dp) = self.sharded.take() else {
            return Err(anyhow!("no serving session: call begin_serving first"));
        };
        if let Err(e) = dp.request_refit(&mut self.comm) {
            self.sharded = Some(dp);
            return Err(e);
        }
        let mut scratch = std::mem::take(&mut self.scratch);
        let stats = self.stats_collective(x, &mut scratch);
        self.scratch = scratch;
        let result = match stats.and_then(|st| self.core_from_stats(x, &st)) {
            Ok(core) => dp.rebroadcast(core, &mut self.comm),
            Err(e) => Err(e),
        };
        self.sharded = Some(dp);
        result
    }

    /// Worker half of a stats-only round (entered on a STATS verb from
    /// the training loop or a REFIT sub-command from a serving session):
    /// receive the parameter broadcast and contribute this rank's chunk
    /// slots to the reduction. A local failure is flagged on the wire
    /// (the collective stays in lockstep) and returned for the worker's
    /// sticky error.
    fn worker_stats_half(&mut self, scratch: &mut CycleScratch) -> Result<()> {
        let gx = self.comm.bcast(0, Vec::new())?;
        if gx.len() != self.layout.global_len() {
            // A short/garbled parameter wire would slice out of bounds
            // below. The caller treats this error as sticky and keeps
            // serving, so the collective must stay in lockstep: ship an
            // all-zero fail-flagged wire through the reduction (the
            // leader counts the flag and abandons the swap), then
            // surface the breach.
            let slot = view_stats_wire_len(self.layout.m, self.ds[0]);
            let wire_len = self.num_chunks * slot;
            scratch.stats_wire.clear();
            seal_wire(&mut scratch.stats_wire, false, wire_len);
            self.comm.reduce_sum_into(0, &mut scratch.stats_wire)?;
            return Err(anyhow!(
                "global-parameter wire: got {} elements, expected {}",
                gx.len(), self.layout.global_len()));
        }
        let globals = unpack_globals(&self.layout, &pad_globals(&self.layout, &gx));
        let (_, err) = self.stats_round(&globals, scratch)?;
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    // -----------------------------------------------------------------
    // leader side
    // -----------------------------------------------------------------

    /// Drive one full distributed cycle at `x`. Returns `(F, ∇F)` — the
    /// *maximised* bound and its gradient; the trainer flips signs for
    /// the minimiser. On error the collectives stay in lockstep: workers
    /// park back at the command broadcast, ready for the next `eval` or
    /// `finish`.
    pub fn eval(&mut self, x: &[f64]) -> Result<(f64, Vec<f64>)> {
        if self.sharded.is_some() {
            // Workers are parked in the serving loop; an EVAL broadcast
            // would be misread as a serve sub-command and desync the
            // cluster. Refuse instead.
            return Err(anyhow!("a serving session is open: call end_serving first"));
        }
        // Scratch is taken out for the call so `self`'s other fields stay
        // freely borrowable alongside it; restored even on error.
        let mut scratch = std::mem::take(&mut self.scratch);
        let out = if self.pipeline {
            self.eval_pipelined(x, &mut scratch)
        } else {
            self.eval_sync(x, &mut scratch)
        };
        self.scratch = scratch;
        if out.is_ok() && !self.layout.variational {
            // Free end-of-run stats: remember this evaluation's reduced
            // view-0 statistics, keyed by the exact parameter vector.
            // When the optimiser's final accepted point is the last
            // evaluation (L-BFGS and SCG accept the point they just
            // evaluated; Adam evaluates after every step), the serving
            // posterior rebuild at the fitted parameters becomes a pure
            // leader-side computation — zero extra collective rounds
            // (see `posterior_core_at`). Buffers are reused, so the
            // steady-state cost is two memcpys per evaluation.
            self.captured_x.clear();
            self.captured_x.extend_from_slice(x);
            self.captured_stats.clear();
            self.scratch.view_stats[0].pack_into(&mut self.captured_stats);
            self.captured = true;
        }
        out
    }

    /// Steps 1–3 at the leader: command + global-parameter broadcast,
    /// (μ, S) span scatter, and the rank-0 latent refresh. Shared by
    /// both schedules.
    fn leader_distribute(&mut self, x: &[f64], scratch: &mut CycleScratch) -> Result<()> {
        let layout = &self.layout;
        let q = layout.q;
        let views = layout.views;
        let view_len = layout.view_len();
        let variational = layout.variational;

        if variational {
            scratch.mu_all.clear();
            scratch.mu_all.extend_from_slice(layout.mu_slice(x));
            scratch.s_all.clear();
            scratch.s_all.extend(layout.log_s_slice(x).iter().map(|v| v.exp()));
        }

        let comm = &mut self.comm;
        let spans = &self.spans;
        let (mu_all, s_all) = (&scratch.mu_all, &scratch.s_all);
        self.timer.time(Phase::Bcast, || -> Result<()> {
            comm.bcast(0, vec![CMD_EVAL])?;
            comm.bcast(0, x[..views * view_len].to_vec())?;
            if variational {
                for (r, span) in spans.iter().enumerate().skip(1) {
                    if let Some(sp) = span {
                        let lo = sp.start * q;
                        let hi = sp.end * q;
                        let mut msg = Vec::with_capacity(2 * (hi - lo));
                        msg.extend_from_slice(&mu_all[lo..hi]);
                        msg.extend_from_slice(&s_all[lo..hi]);
                        comm.send(r, TAG_LOCALS, &msg)?;
                    }
                }
            }
            Ok(())
        })?;

        if variational {
            let sp = self.spans[0]
                .ok_or_else(|| anyhow!("variational layout without a rank-0 span"))?;
            let (lo, hi) = (sp.start * q, sp.end * q);
            refresh_latents(&mut scratch.latents, &self.state.view_chunks[0], sp.start,
                            q, &scratch.mu_all[lo..hi], &scratch.s_all[lo..hi]);
        }
        Ok(())
    }

    /// Unpack view v's reduced statistics (sitting at the head of
    /// `stats_wire`) and run the M×M core. `fails` is the view's reduced
    /// fail count; a local fwd error takes precedence.
    fn view_core(&mut self, v: usize, globals: &GlobalParams,
                 scratch: &mut CycleScratch, fails: f64,
                 fwd_err: &mut Option<anyhow::Error>)
                 -> Result<crate::math::bound::BoundOut> {
        if let Some(e) = fwd_err.take() {
            return Err(e);
        }
        if fails > 0.0 {
            return Err(anyhow!("stats_fwd failed on {fails} rank(s)"));
        }
        let m = self.layout.m;
        let len = view_stats_wire_len(m, self.ds[v]);
        scratch.view_stats[v].unpack_from(&scratch.stats_wire[..len]);
        let kern = RbfArd::from_log_hyp(&globals.views[v].log_hyp);
        bound_and_grads(&scratch.view_stats[v], &globals.views[v].z, &kern,
                        globals.views[v].log_beta)
    }

    /// The pipelined leader schedule (see the module doc's diagram).
    fn eval_pipelined(&mut self, x: &[f64], scratch: &mut CycleScratch)
                      -> Result<(f64, Vec<f64>)> {
        let (m, q) = (self.layout.m, self.layout.q);
        let variational = self.layout.variational;
        let views = self.layout.views;
        let view_len = self.layout.view_len();
        let globals = unpack_globals(&self.layout, x);

        self.leader_distribute(x, scratch)?;
        self.reset_span_grads(scratch);

        let mut fwd_err: Option<anyhow::Error> = None;
        let mut vjp_err: Option<anyhow::Error> = None;
        let mut f_total = 0.0;
        let mut grad = vec![0.0; self.layout.len()];

        // 4(v=0): first view's forward + reduction
        let mut fails = self.fwd_reduce_view(0, &globals, scratch, &mut fwd_err)?;

        for v in 0..views {
            // 5: view v's M×M core from the just-reduced statistics
            let t0 = Instant::now();
            let core = self.view_core(v, &globals, scratch, fails, &mut fwd_err);
            self.timer.add(Phase::BoundCore, t0.elapsed());

            let out = match core {
                Ok(out) => out,
                Err(e) => {
                    // Abort at view v: empty cotangent broadcast, then
                    // absorb the one fwd reduction the workers issued
                    // before they could observe the abort, and truncate
                    // the rest of the cycle on both sides.
                    let comm = &mut self.comm;
                    self.timer.time(Phase::Bcast, || comm.bcast(0, Vec::new()))?;
                    if v + 1 < views {
                        let wire_len = view_stats_wire_len(m, self.ds[v + 1]);
                        scratch.stats_wire.clear();
                        seal_wire(&mut scratch.stats_wire, false, wire_len);
                        self.comm.reduce_sum_into(0, &mut scratch.stats_wire)?;
                    }
                    return Err(e);
                }
            };
            f_total += out.f;

            // 5b: view v's cotangents go out (non-blocking sends), so
            // workers can start vjp[v] while the leader is still busy
            // with its own fwd[v+1] below.
            {
                let comm = &mut self.comm;
                let cts_wire = &mut scratch.cts_wire;
                let cts = &out.cts;
                self.timer.time(Phase::Bcast, || -> Result<()> {
                    cts_wire.clear();
                    cts.pack_into(cts_wire);
                    *cts_wire = comm.bcast(0, std::mem::take(cts_wire))?;
                    Ok(())
                })?;
            }

            // 4(v+1): next view's forward + reduction — in flight while
            // this view's vjp runs everywhere.
            fails = if v + 1 < views {
                self.fwd_reduce_view(v + 1, &globals, scratch, &mut fwd_err)?
            } else {
                0.0
            };

            // 6/7a: view v's vjp + grads reduction
            let ok = self.vjp_reduce_view(v, &globals, &out.cts, scratch, false,
                                          &mut vjp_err)?;
            let gfails = scratch.grads_wire.last().copied()
                .ok_or_else(|| anyhow!("empty grads reduce wire"))?;
            if vjp_err.is_none() && (!ok || gfails > 0.0) {
                vjp_err = Some(anyhow!("stats_vjp failed on {gfails} rank(s)"));
            }

            // assemble view v's slice of ∇F from the reduced partials
            if vjp_err.is_none() {
                let o = v * view_len;
                let gred = &scratch.grads_wire;
                for i in 0..q + 1 {
                    grad[o + i] = out.dhyp[i] + gred[m * q + i];
                }
                grad[o + q + 1] = out.dlog_beta;
                for i in 0..m * q {
                    grad[o + q + 2 + i] = out.dz.as_slice()[i] + gred[i];
                }
            }
        }

        // 7b: gather the span-local gradients. A compute-side vjp error
        // takes precedence over any transport error from the gather.
        let t0 = Instant::now();
        let locals = self.gather_locals(scratch, vjp_err.is_none());
        if let Some(e) = vjp_err {
            self.timer.add(Phase::GatherGrads, t0.elapsed());
            return Err(e);
        }
        let locals = locals?;
        if variational {
            let locals = locals
                .ok_or_else(|| anyhow!("gather returned no data at the root"))?;
            let n = self.layout.n;
            let base_mu = views * view_len;
            let base_ls = base_mu + n * q;
            for (r, piece) in locals.iter().enumerate() {
                if let Some(sp) = self.spans[r] {
                    let len = (sp.end - sp.start) * q;
                    debug_assert_eq!(piece.len(), 2 * len);
                    grad[base_mu + sp.start * q..base_mu + sp.end * q]
                        .copy_from_slice(&piece[..len]);
                    grad[base_ls + sp.start * q..base_ls + sp.end * q]
                        .copy_from_slice(&piece[len..2 * len]);
                }
            }
        }
        self.timer.add(Phase::GatherGrads, t0.elapsed());
        self.timer.note_eval();

        Ok((f_total, grad))
    }

    /// The synchronous reference schedule: whole-cycle wires, one
    /// reduction per direction (the pre-pipeline protocol, kept as the
    /// escape hatch and the equivalence baseline).
    fn eval_sync(&mut self, x: &[f64], scratch: &mut CycleScratch)
                 -> Result<(f64, Vec<f64>)> {
        let (m, q) = (self.layout.m, self.layout.q);
        let variational = self.layout.variational;
        let views = self.layout.views;
        let view_len = self.layout.view_len();
        let globals = unpack_globals(&self.layout, x);

        self.leader_distribute(x, scratch)?;

        // 4: local fwd over all views + one reduction (trailing element
        // counts failed ranks)
        let swire_len = stats_wire_len(m, &self.ds);
        let t0 = Instant::now();
        let c0 = self.clock();
        scratch.stats_wire.clear();
        let mut fwd_err: Option<anyhow::Error> = None;
        for v in 0..views {
            match self.state.fwd_view(v, &globals.views[v], &scratch.latents, m,
                                      self.ds[v]) {
                Ok((st, caches)) => {
                    scratch.caches[v] = caches;
                    st.pack_into(&mut scratch.stats_wire);
                }
                Err(e) => {
                    fwd_err = Some(e);
                    break;
                }
            }
        }
        self.compute += self.clock() - c0;
        self.timer.add(Phase::StatsFwd, t0.elapsed());

        seal_wire(&mut scratch.stats_wire, fwd_err.is_none(), swire_len);
        let t0 = Instant::now();
        let res = self.comm.reduce_sum_into(0, &mut scratch.stats_wire);
        self.timer.add(Phase::Reduce, t0.elapsed());
        res?;
        let fwd_fails = scratch.stats_wire.last().copied()
            .ok_or_else(|| anyhow!("empty stats reduce wire"))?;

        // 5: the indistributable core
        let t0 = Instant::now();
        let core = if let Some(e) = fwd_err {
            Err(e)
        } else if fwd_fails > 0.0 {
            Err(anyhow!("stats_fwd failed on {fwd_fails} rank(s)"))
        } else {
            let mut f_total = 0.0;
            let mut all_cts = Vec::with_capacity(views);
            let mut direct = Vec::with_capacity(views);
            let mut off = 0;
            let mut core_err = None;
            for (v, &d) in self.ds.iter().enumerate() {
                let len = view_stats_wire_len(m, d);
                scratch.view_stats[v].unpack_from(&scratch.stats_wire[off..off + len]);
                off += len;
                let kern = RbfArd::from_log_hyp(&globals.views[v].log_hyp);
                match bound_and_grads(&scratch.view_stats[v], &globals.views[v].z,
                                      &kern, globals.views[v].log_beta) {
                    Ok(out) => {
                        f_total += out.f;
                        all_cts.push(out.cts);
                        direct.push((out.dz, out.dhyp, out.dlog_beta));
                    }
                    Err(e) => {
                        core_err = Some(e);
                        break;
                    }
                }
            }
            match core_err {
                Some(e) => Err(e),
                None => Ok((f_total, all_cts, direct)),
            }
        };
        self.timer.add(Phase::BoundCore, t0.elapsed());

        // 5b: cotangent broadcast — empty aborts the cycle in lockstep
        let (f_total, all_cts, direct) = match core {
            Ok(parts) => {
                let comm = &mut self.comm;
                let cts_wire = &mut scratch.cts_wire;
                let all = &parts.1;
                self.timer.time(Phase::Bcast, || -> Result<()> {
                    cts_wire.clear();
                    for cts in all {
                        cts.pack_into(cts_wire);
                    }
                    *cts_wire = comm.bcast(0, std::mem::take(cts_wire))?;
                    Ok(())
                })?;
                parts
            }
            Err(e) => {
                let comm = &mut self.comm;
                self.timer.time(Phase::Bcast, || comm.bcast(0, Vec::new()))?;
                return Err(e);
            }
        };

        // 6: local vjp over all views
        self.reset_span_grads(scratch);
        let gwire_len = grads_wire_len(m, q, views);
        let t0 = Instant::now();
        let c0 = self.clock();
        scratch.grads_wire.clear();
        let mut vjp_err: Option<anyhow::Error> = None;
        for v in 0..views {
            match self.state.vjp_view(v, &globals.views[v], &all_cts[v],
                                      &scratch.latents, &scratch.caches[v],
                                      &mut scratch.dmu_span, &mut scratch.dls_span, m) {
                Ok((dz, dhyp)) => {
                    scratch.grads_wire.extend_from_slice(dz.as_slice());
                    scratch.grads_wire.extend_from_slice(&dhyp);
                }
                Err(e) => {
                    vjp_err = Some(e);
                    break;
                }
            }
        }
        self.compute += self.clock() - c0;
        self.timer.add(Phase::StatsVjp, t0.elapsed());

        // 7: reduce global partials + gather locals (fail flag again).
        // A compute-side vjp error outranks transport errors from the
        // closing collectives.
        seal_wire(&mut scratch.grads_wire, vjp_err.is_none(), gwire_len);
        let t0 = Instant::now();
        let gres = self.comm.reduce_sum_into(0, &mut scratch.grads_wire);
        let locals = self.gather_locals(scratch, vjp_err.is_none());
        self.timer.add(Phase::GatherGrads, t0.elapsed());

        if let Some(e) = vjp_err {
            return Err(e);
        }
        gres?;
        let locals = locals?;
        let vjp_fails = scratch.grads_wire.last().copied()
            .ok_or_else(|| anyhow!("empty grads reduce wire"))?;
        if vjp_fails > 0.0 {
            return Err(anyhow!("stats_vjp failed on {vjp_fails} rank(s)"));
        }

        // assemble ∇F
        let t0 = Instant::now();
        let mut grad = vec![0.0; self.layout.len()];
        let greduced = &scratch.grads_wire;
        let mut goff = 0;
        for (v, (dz_direct, dhyp_direct, dlog_beta)) in direct.iter().enumerate() {
            let o = v * view_len;
            let dz_part = &greduced[goff..goff + m * q];
            goff += m * q;
            let dhyp_part = &greduced[goff..goff + q + 1];
            goff += q + 1;
            for i in 0..q + 1 {
                grad[o + i] = dhyp_direct[i] + dhyp_part[i];
            }
            grad[o + q + 1] = *dlog_beta;
            for i in 0..m * q {
                grad[o + q + 2 + i] = dz_direct.as_slice()[i] + dz_part[i];
            }
        }
        if variational {
            let locals = locals
                .ok_or_else(|| anyhow!("gather returned no data at the root"))?;
            let n = self.layout.n;
            let base_mu = views * view_len;
            let base_ls = base_mu + n * q;
            for (r, piece) in locals.iter().enumerate() {
                if let Some(sp) = self.spans[r] {
                    let len = (sp.end - sp.start) * q;
                    debug_assert_eq!(piece.len(), 2 * len);
                    grad[base_mu + sp.start * q..base_mu + sp.end * q]
                        .copy_from_slice(&piece[..len]);
                    grad[base_ls + sp.start * q..base_ls + sp.end * q]
                        .copy_from_slice(&piece[len..2 * len]);
                }
            }
        }
        self.timer.add(Phase::GatherGrads, t0.elapsed());
        self.timer.note_eval();

        Ok((f_total, grad))
    }

    /// Leader: stop the workers and collect every rank's distributable
    /// compute-seconds (indexed by rank). A still-open serving session
    /// is closed first, so the workers are back at the command broadcast
    /// when the STOP lands (a raw STOP would be misread inside the
    /// serving loop and deadlock the shutdown).
    pub fn finish(&mut self) -> Vec<f64> {
        if self.sharded.is_some() {
            let _ = self.end_serving();
        }
        // Best-effort: a dead worker must not turn shutdown into a
        // panic; the caller just loses the compute-seconds report.
        if self.comm.bcast(0, vec![CMD_STOP]).is_err() {
            return Vec::new();
        }
        match self.comm.gather(0, &[self.compute]) {
            Ok(Some(per_rank)) => per_rank
                .into_iter()
                .map(|v| v.first().copied().unwrap_or(0.0))
                .collect(),
            _ => Vec::new(),
        }
    }

    // -----------------------------------------------------------------
    // leader side: sharded serving
    // -----------------------------------------------------------------

    /// Leader: switch the cluster into a sharded serving session —
    /// broadcast the precomputed posterior once; workers leave the
    /// training command loop and enter the serving loop. Batches then go
    /// through [`predict_sharded`](DistributedEvaluator::predict_sharded)
    /// until [`end_serving`](DistributedEvaluator::end_serving) hands
    /// the workers back to the training loop.
    pub fn begin_serving(&mut self, core: PosteriorCore, rows_per_chunk: usize)
                         -> Result<()> {
        if self.sharded.is_some() {
            return Err(anyhow!("a serving session is already open"));
        }
        self.comm.bcast(0, vec![CMD_SERVE])?;
        self.sharded = Some(DistributedPosterior::leader(core, rows_per_chunk,
                                                         &mut self.comm)?);
        Ok(())
    }

    /// Leader: predict one batch through the open serving session,
    /// sharded across every rank of the cluster (rank 0 computes its own
    /// shard through the same backend it trains with).
    pub fn predict_sharded(&mut self, xstar: &Mat) -> Result<(Mat, Vec<f64>)> {
        match self.sharded.as_mut() {
            None => Err(anyhow!("no serving session: call begin_serving first")),
            Some(dp) => dp.predict(&mut self.comm, self.state.backends[0].as_mut(),
                                   xstar),
        }
    }

    /// Leader: serve a run of batches through the open serving session
    /// as a **stream** — batch k+1's announcement and shard sends go out
    /// before batch k's gather is collected, so the serving workers roll
    /// straight from one batch into the next
    /// ([`DistributedPosterior::predict_stream`]; bit-identical to
    /// calling [`predict_sharded`](DistributedEvaluator::predict_sharded)
    /// per batch).
    pub fn predict_stream_sharded(&mut self, batches: &[Mat])
                                  -> Result<Vec<(Mat, Vec<f64>)>> {
        match self.sharded.as_mut() {
            None => Err(anyhow!("no serving session: call begin_serving first")),
            Some(dp) => dp.predict_stream(&mut self.comm,
                                          self.state.backends[0].as_mut(), batches),
        }
    }

    /// Leader: drive a [`ServingFrontend`]'s micro-batcher over the
    /// open serving session — concurrent client handles enqueue rows,
    /// the batcher coalesces them through the streamed issue/complete
    /// machinery, and replies fan back out
    /// ([`super::frontend`] has the full semantics). Returns when the
    /// front-end is closed and drained; the serving session itself stays
    /// open. On a training cluster,
    /// [`refit`](super::frontend::FrontendHandle::refit) works: it
    /// routes through
    /// [`refit_and_swap`](DistributedEvaluator::refit_and_swap) on a
    /// batch boundary.
    pub fn serve_frontend(&mut self, fe: &ServingFrontend) -> Result<ServingReport> {
        if self.sharded.is_none() {
            return Err(anyhow!("no serving session: call begin_serving first"));
        }
        let mut drv = EvaluatorServeDriver { ev: self };
        Ok(fe.run_driver(&mut drv))
    }

    /// Leader: close the serving session (workers park back at the
    /// training command broadcast, ready for `eval` or `finish`).
    pub fn end_serving(&mut self) -> Result<()> {
        match self.sharded.take() {
            None => Err(anyhow!("no serving session is open")),
            Some(mut dp) => dp.finish(&mut self.comm),
        }
    }

    // -----------------------------------------------------------------
    // worker side
    // -----------------------------------------------------------------

    /// Worker loop: obey broadcast commands until STOP. A compute failure
    /// is reported to the leader through the fail-count elements while
    /// the rank keeps the collectives in lockstep; the first such error
    /// is returned once the leader shuts the cluster down.
    pub fn serve(&mut self) -> Result<()> {
        let mut scratch = std::mem::take(&mut self.scratch);
        let out = if self.pipeline {
            self.serve_pipelined(&mut scratch)
        } else {
            self.serve_sync(&mut scratch)
        };
        self.scratch = scratch;
        out
    }

    /// Steps 1–3 on a worker: obey the command broadcast, unpack the
    /// globals, receive the (μ, S) span and refresh the latent slices.
    /// A malformed verb or wrong-length payload errors out of the worker
    /// loop entirely (the dropped transport then hangs up on peers, so
    /// the cluster cascades to termination instead of deadlocking).
    fn worker_receive(&mut self, scratch: &mut CycleScratch) -> Result<WorkerCmd> {
        let cmd = self.comm.bcast(0, Vec::new())?;
        if cmd.is_empty() || cmd[0] == CMD_STOP {
            return Ok(WorkerCmd::Stop);
        }
        if cmd[0] == CMD_SERVE {
            return Ok(WorkerCmd::Serve);
        }
        if cmd[0] == CMD_STATS {
            return Ok(WorkerCmd::Stats);
        }
        if cmd[0] != CMD_EVAL {
            return Err(anyhow!("unknown command verb {} on the cluster wire",
                               cmd[0]));
        }
        let gx = self.comm.bcast(0, Vec::new())?;
        if gx.len() != self.layout.global_len() {
            return Err(anyhow!(
                "global-parameter broadcast: got {} elements, expected {}",
                gx.len(), self.layout.global_len()));
        }
        let globals = unpack_globals(&self.layout, &pad_globals(&self.layout, &gx));

        if self.layout.variational {
            if let Some(sp) = self.state.span {
                let q = self.layout.q;
                let msg = self.comm.recv(0, TAG_LOCALS)?;
                let len = (sp.end - sp.start) * q;
                if msg.len() != 2 * len {
                    return Err(anyhow!(
                        "span scatter for rank {}: got {} elements, expected {}",
                        self.comm.rank(), msg.len(), 2 * len));
                }
                refresh_latents(&mut scratch.latents, &self.state.view_chunks[0],
                                sp.start, q, &msg[..len], &msg[len..]);
            }
        }
        Ok(WorkerCmd::Eval(globals))
    }

    /// Worker side of a whole serving session (entered on CMD_SERVE,
    /// returns when the leader closes it). A serving failure is reported
    /// through the session's own fail-flag protocol; the returned error
    /// is merged into the worker loop's sticky error. REFIT sub-commands
    /// send this rank through one stats-only round (the worker half of
    /// [`refit_and_swap`](DistributedEvaluator::refit_and_swap)); the
    /// leader follows a successful refit with a swap broadcast, which
    /// the serve loop applies internally.
    fn worker_serve_session(&mut self, scratch: &mut CycleScratch) -> Result<()> {
        let mut dp = DistributedPosterior::worker(&mut self.comm)?;
        let mut sticky: Option<anyhow::Error> = None;
        loop {
            match dp.serve_until(&mut self.comm, self.state.backends[0].as_mut()) {
                Ok(ServeSignal::Done) => {
                    return match sticky {
                        Some(e) => Err(e),
                        None => Ok(()),
                    };
                }
                Ok(ServeSignal::Refit) => {
                    // a local stats failure is flagged on the wire (the
                    // leader then abandons the swap cluster-wide), so
                    // serving continues against the old posterior
                    if let Err(e) = self.worker_stats_half(scratch) {
                        if sticky.is_none() {
                            sticky = Some(e);
                        }
                    }
                }
                Err(e) => {
                    // the session's own first-error-wins stream is the
                    // primary diagnostic; a refit stats-round failure is
                    // appended rather than allowed to mask it
                    return match sticky {
                        Some(s) => Err(anyhow!(
                            "{e:#}; also failed a refit stats round: {s:#}")),
                        None => Err(e),
                    };
                }
            }
        }
    }

    /// The pipelined worker schedule: mirror image of `eval_pipelined` —
    /// the same global collective order, with the next view's forward
    /// shipped before blocking on this view's cotangents.
    fn serve_pipelined(&mut self, scratch: &mut CycleScratch) -> Result<()> {
        let m = self.layout.m;
        let views = self.layout.views;
        let rank = self.comm.rank();
        let mut sticky_err: Option<anyhow::Error> = None;

        loop {
            let globals = match self.worker_receive(scratch)? {
                WorkerCmd::Eval(g) => g,
                WorkerCmd::Serve => {
                    if let Err(e) = self.worker_serve_session(scratch) {
                        if sticky_err.is_none() {
                            sticky_err = Some(e);
                        }
                    }
                    continue;
                }
                WorkerCmd::Stats => {
                    if let Err(e) = self.worker_stats_half(scratch) {
                        if sticky_err.is_none() {
                            sticky_err = Some(e);
                        }
                    }
                    continue;
                }
                WorkerCmd::Stop => {
                    let _ = self.comm.gather(0, &[self.compute]);
                    return match sticky_err {
                        Some(e) => Err(anyhow!("rank {rank}: {e:#}")),
                        None => Ok(()),
                    };
                }
            };
            self.reset_span_grads(scratch);

            let mut fwd_err: Option<anyhow::Error> = None;
            let mut vjp_err: Option<anyhow::Error> = None;
            let mut vjp_ok = true;
            let mut aborted = false;

            self.fwd_reduce_view(0, &globals, scratch, &mut fwd_err)?;

            for v in 0..views {
                // ship the next view's forward before blocking on this
                // view's cotangents — that reduce is what the leader's
                // core work overlaps with
                if v + 1 < views {
                    self.fwd_reduce_view(v + 1, &globals, scratch, &mut fwd_err)?;
                }

                let cwire = self.comm.bcast(0, Vec::new())?;
                if cwire.is_empty() {
                    // leader aborted at view v; truncate the cycle the
                    // same way it does (no vjp[v..], no gather)
                    aborted = true;
                    break;
                }
                let want = 3 + m * self.ds[v] + m * m;
                if cwire.len() != want {
                    return Err(anyhow!(
                        "cotangent wire for view {v}: got {} elements, \
                         expected {want}", cwire.len()));
                }
                scratch.view_cts[v].unpack_from(&cwire);

                // a fwd failure on this rank skips the vjp (the leader
                // aborts at the flagged view; see serve_sync)
                let skip = fwd_err.is_some() || !vjp_ok;
                let cts = std::mem::replace(&mut scratch.view_cts[v],
                                            StatsCts::zeros(0, 0));
                let ok = self.vjp_reduce_view(v, &globals, &cts, scratch, skip,
                                              &mut vjp_err)?;
                scratch.view_cts[v] = cts;
                if !ok {
                    vjp_ok = false;
                }
            }

            if !aborted {
                let _ = self.gather_locals(scratch, vjp_ok)?;
            }
            if sticky_err.is_none() {
                if let Some(e) = fwd_err {
                    sticky_err = Some(e);
                } else if let Some(e) = vjp_err {
                    sticky_err = Some(e);
                }
            }
        }
    }

    /// The synchronous worker schedule (whole-cycle wires).
    fn serve_sync(&mut self, scratch: &mut CycleScratch) -> Result<()> {
        let (m, q) = (self.layout.m, self.layout.q);
        let views = self.layout.views;
        let rank = self.comm.rank();
        let mut sticky_err: Option<anyhow::Error> = None;

        loop {
            let globals = match self.worker_receive(scratch)? {
                WorkerCmd::Eval(g) => g,
                WorkerCmd::Serve => {
                    if let Err(e) = self.worker_serve_session(scratch) {
                        if sticky_err.is_none() {
                            sticky_err = Some(e);
                        }
                    }
                    continue;
                }
                WorkerCmd::Stats => {
                    if let Err(e) = self.worker_stats_half(scratch) {
                        if sticky_err.is_none() {
                            sticky_err = Some(e);
                        }
                    }
                    continue;
                }
                WorkerCmd::Stop => {
                    let _ = self.comm.gather(0, &[self.compute]);
                    return match sticky_err {
                        Some(e) => Err(anyhow!("rank {rank}: {e:#}")),
                        None => Ok(()),
                    };
                }
            };

            // fwd over all views + one reduction (with fail flag)
            let c0 = self.clock();
            scratch.stats_wire.clear();
            let mut fwd_err: Option<anyhow::Error> = None;
            for v in 0..views {
                match self.state.fwd_view(v, &globals.views[v], &scratch.latents, m,
                                          self.ds[v]) {
                    Ok((st, caches)) => {
                        scratch.caches[v] = caches;
                        st.pack_into(&mut scratch.stats_wire);
                    }
                    Err(e) => {
                        fwd_err = Some(e);
                        break;
                    }
                }
            }
            self.compute += self.clock() - c0;
            seal_wire(&mut scratch.stats_wire, fwd_err.is_none(),
                      stats_wire_len(m, &self.ds));
            self.comm.reduce_sum_into(0, &mut scratch.stats_wire)?;
            if let Some(e) = fwd_err.as_ref() {
                if sticky_err.is_none() {
                    sticky_err = Some(anyhow!("{e:#}"));
                }
            }

            // cts (empty = leader aborted the cycle)
            let cwire = self.comm.bcast(0, Vec::new())?;
            if cwire.is_empty() {
                continue;
            }
            let want: usize = self.ds.iter().map(|&d| 3 + m * d + m * m).sum();
            if cwire.len() != want {
                return Err(anyhow!(
                    "cotangent wire: got {} elements, expected {want}",
                    cwire.len()));
            }
            let mut off = 0;
            for (v, &d) in self.ds.iter().enumerate() {
                let len = 3 + m * d + m * m;
                scratch.view_cts[v].unpack_from(&cwire[off..off + len]);
                off += len;
            }

            // vjp + reduce + gather (fail flag on the reduce)
            self.reset_span_grads(scratch);
            scratch.grads_wire.clear();
            let mut vjp_ok = fwd_err.is_none();
            if vjp_ok {
                let c0 = self.clock();
                for v in 0..views {
                    let cts = std::mem::replace(&mut scratch.view_cts[v],
                                                StatsCts::zeros(0, 0));
                    let res = self.state.vjp_view(v, &globals.views[v], &cts,
                                                  &scratch.latents, &scratch.caches[v],
                                                  &mut scratch.dmu_span,
                                                  &mut scratch.dls_span, m);
                    scratch.view_cts[v] = cts;
                    match res {
                        Ok((dz, dhyp)) => {
                            scratch.grads_wire.extend_from_slice(dz.as_slice());
                            scratch.grads_wire.extend_from_slice(&dhyp);
                        }
                        Err(e) => {
                            if sticky_err.is_none() {
                                sticky_err = Some(e);
                            }
                            vjp_ok = false;
                            break;
                        }
                    }
                }
                self.compute += self.clock() - c0;
            }
            seal_wire(&mut scratch.grads_wire, vjp_ok, grads_wire_len(m, q, views));
            self.comm.reduce_sum_into(0, &mut scratch.grads_wire)?;
            let _ = self.gather_locals(scratch, vjp_ok)?;
        }
    }
}

/// The serving front-end's view of a training cluster: the batch
/// issue/complete halves go through the evaluator's open serving
/// session (`sharded`) with its own comm and rank-0 backend, and the
/// `Refit` control routes through the distributed stats pass
/// ([`DistributedEvaluator::refit_and_swap`]) — the one thing the
/// standalone driver cannot do.
struct EvaluatorServeDriver<'a> {
    ev: &'a mut DistributedEvaluator,
}

impl EvaluatorServeDriver<'_> {
    /// The open session (checked by `serve_frontend` before the batcher
    /// starts; nothing closes it mid-run).
    fn dp_and_ctx(&mut self) -> (&mut DistributedPosterior, &mut Comm, &mut dyn Backend) {
        let ev = &mut *self.ev;
        // lint: allow(no-unwrap-protocol) — `serve_frontend` checks the
        // session is open before constructing this driver and nothing
        // closes it mid-run; the trait methods return only `Result`s
        // from the serving protocol itself, so a missing session here
        // is a local logic bug, not a recoverable wire condition.
        (ev.sharded.as_mut().expect("serving session checked open"),
         &mut ev.comm, ev.state.backends[0].as_mut())
    }
}

impl ServeDriver for EvaluatorServeDriver<'_> {
    fn prepare(&mut self, batch: &Mat, mean: &mut Mat, var: &mut Vec<f64>)
               -> Result<()> {
        let (dp, _, _) = self.dp_and_ctx();
        dp.prepare_outputs(batch, mean, var)
    }

    fn issue(&mut self, batch: &Mat, stream: bool) -> Result<()> {
        let (dp, comm, _) = self.dp_and_ctx();
        dp.issue_batch(comm, batch, stream)
    }

    fn complete(&mut self, batch: &Mat, mean: &mut Mat, var: &mut Vec<f64>)
                -> Result<()> {
        let (dp, comm, backend) = self.dp_and_ctx();
        dp.complete_batch(comm, backend, batch, mean, var)
    }

    fn control(&mut self, op: ControlOp) -> Result<()> {
        match op {
            ControlOp::Swap(core) => {
                let (dp, comm, _) = self.dp_and_ctx();
                dp.rebroadcast(*core, comm)
            }
            // a failed refit is atomic (no swap broadcast): the session
            // keeps serving the old posterior and the error goes back to
            // the control's caller
            ControlOp::Refit(x) => self.ev.refit_and_swap(&x),
        }
    }

    fn comm_counters(&self) -> (u64, u64) {
        (self.ev.comm.bytes_sent(), self.ev.comm.messages_sent())
    }
}

/// Monotonic wall clock as seconds-since-first-use (for intra-rank
/// parallel backends, whose work the per-thread CPU clock cannot see).
fn thread_wall_time() -> f64 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}
