//! Data partitioning: N datapoints -> fixed-size chunks -> workers.
//!
//! Chunks are fixed-shape (the AOT artifacts are compiled for a static
//! chunk size C); the ragged tail is padded and masked with w ∈ {0,1}.
//! Workers receive *contiguous* runs of chunks so their local parameter
//! slices (μ, S rows) are contiguous ranges of the global matrices.
//!
//! Store-backed problems partition **by manifest chunk id**
//! ([`Partition::from_manifest`]): the store's chunk grid *is* the
//! partition grid, the per-chunk summary statistics gate assignment
//! (a manifest with non-finite stats is rejected before any rank
//! touches the data), and degenerate zero-row tail chunks are skipped.

use crate::data::store::StoreManifest;
use anyhow::{bail, Result};

/// A contiguous run of datapoint indices `[start, end)`, `end − start ≤ C`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkRange {
    /// First datapoint index (inclusive).
    pub start: usize,
    /// One past the last datapoint index.
    pub end: usize,
}

impl ChunkRange {
    /// Number of datapoints in the range.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Is the range empty?
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// The full assignment of chunks to workers.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Total datapoint count.
    pub n: usize,
    /// Fixed chunk size C (the last chunk may be shorter).
    pub chunk: usize,
    /// `per_worker[r]` = the chunks owned by rank r (contiguous run).
    pub per_worker: Vec<Vec<ChunkRange>>,
}

/// Deal a flat ordered chunk list across `workers` ranks in contiguous,
/// balanced runs: first (k % workers) ranks get one extra chunk.
fn deal_contiguous(chunks: &[ChunkRange], workers: usize) -> Vec<Vec<ChunkRange>> {
    let k = chunks.len();
    let mut per_worker = vec![Vec::new(); workers];
    let base = k / workers;
    let extra = k % workers;
    let mut idx = 0;
    for (r, bucket) in per_worker.iter_mut().enumerate() {
        let take = base + usize::from(r < extra);
        for _ in 0..take {
            bucket.push(chunks[idx]);
            idx += 1;
        }
    }
    per_worker
}

impl Partition {
    /// Split `n` datapoints into `⌈n/chunk⌉` chunks and deal them out to
    /// `workers` ranks in contiguous, balanced runs.
    pub fn new(n: usize, chunk: usize, workers: usize) -> Partition {
        assert!(chunk > 0 && workers > 0 && n > 0);
        let chunks: Vec<ChunkRange> = (0..n)
            .step_by(chunk)
            .map(|s| ChunkRange { start: s, end: (s + chunk).min(n) })
            .collect();
        let per_worker = deal_contiguous(&chunks, workers);
        Partition { n, chunk, per_worker }
    }

    /// Partition a chunk store **by manifest chunk id**: chunk `k` of the
    /// store becomes chunk `k` of the partition, so a rank's assignment
    /// doubles as the exact list of store chunks it will stream. The
    /// manifest is re-validated first (offset grid, summary-stat sanity,
    /// Σ rows == n), so a corrupt or degenerate store is rejected here —
    /// before any rank opens the data file. Zero-row chunks cannot occur
    /// in a valid manifest (validation requires every chunk non-empty),
    /// so each assigned range is live by construction.
    ///
    /// For a well-formed store this is equivalent to
    /// `Partition::new(man.n, man.chunk_rows, workers)` — the store's
    /// full-chunk grid discipline makes chunk id ↔ row range pure
    /// arithmetic — which keeps the STATS-round slot mapping
    /// (`slot = start / chunk`) valid for streamed problems.
    pub fn from_manifest(man: &StoreManifest, workers: usize) -> Result<Partition> {
        if workers == 0 {
            bail!("partition: need at least one worker");
        }
        man.validate()?;
        let mut chunks = Vec::with_capacity(man.num_chunks());
        let mut start = 0usize;
        for meta in &man.chunks {
            chunks.push(ChunkRange { start, end: start + meta.rows });
            start += meta.rows;
        }
        let per_worker = deal_contiguous(&chunks, workers);
        Ok(Partition { n: man.n, chunk: man.chunk_rows, per_worker })
    }

    /// The contiguous datapoint range owned by rank r (for local-parameter
    /// slicing); `None` if the rank holds no chunks.
    pub fn worker_span(&self, r: usize) -> Option<ChunkRange> {
        let c = &self.per_worker[r];
        if c.is_empty() {
            None
        } else {
            Some(ChunkRange { start: c[0].start, end: c[c.len() - 1].end })
        }
    }

    /// Number of ranks the chunks are dealt across.
    pub fn workers(&self) -> usize {
        self.per_worker.len()
    }

    /// Total number of chunks.
    pub fn num_chunks(&self) -> usize {
        self.per_worker.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn prop_exact_cover() {
        // Every datapoint appears in exactly one chunk of one worker.
        Prop::new("partition_cover").cases(60).run(|rng| {
            let n = 1 + (rng.next_u64() % 500) as usize;
            let chunk = 1 + (rng.next_u64() % 64) as usize;
            let workers = 1 + (rng.next_u64() % 9) as usize;
            let p = Partition::new(n, chunk, workers);
            let mut seen = vec![0u32; n];
            for bucket in &p.per_worker {
                for c in bucket {
                    assert!(c.len() <= chunk);
                    assert!(c.len() > 0);
                    for i in c.start..c.end {
                        seen[i] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&s| s == 1), "n={n} chunk={chunk} w={workers}");
        });
    }

    #[test]
    fn prop_spans_are_contiguous_and_ordered() {
        Prop::new("partition_spans").cases(40).run(|rng| {
            let n = 1 + (rng.next_u64() % 300) as usize;
            let chunk = 1 + (rng.next_u64() % 50) as usize;
            let workers = 1 + (rng.next_u64() % 6) as usize;
            let p = Partition::new(n, chunk, workers);
            let mut cursor = 0;
            for r in 0..workers {
                if let Some(span) = p.worker_span(r) {
                    assert_eq!(span.start, cursor, "gap before rank {r}");
                    cursor = span.end;
                    // chunks within the worker are contiguous too
                    let mut c2 = span.start;
                    for c in &p.per_worker[r] {
                        assert_eq!(c.start, c2);
                        c2 = c.end;
                    }
                }
            }
            assert_eq!(cursor, n);
        });
    }

    #[test]
    fn balance_within_one_chunk() {
        let p = Partition::new(1000, 10, 7); // 100 chunks over 7 workers
        let counts: Vec<usize> = p.per_worker.iter().map(Vec::len).collect();
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(max - min <= 1, "{counts:?}");
    }

    #[test]
    fn more_workers_than_chunks() {
        let p = Partition::new(10, 10, 4); // 1 chunk, 4 workers
        assert_eq!(p.num_chunks(), 1);
        assert!(p.worker_span(0).is_some());
        assert!(p.worker_span(3).is_none());
    }

    #[test]
    fn prop_manifest_partition_matches_arithmetic_partition() {
        use crate::data::store::{ChunkSource, ResidentStore};
        use crate::linalg::Mat;
        // For a well-formed store, from_manifest ≡ Partition::new over the
        // same (n, chunk_rows, workers) — same grid, same dealing.
        Prop::new("partition_manifest").cases(30).run(|rng| {
            let n = 1 + (rng.next_u64() % 200) as usize;
            let chunk = 1 + (rng.next_u64() % 32) as usize;
            let workers = 1 + (rng.next_u64() % 9) as usize;
            let y = Mat::from_fn(n, 2, |i, j| (i * 2 + j) as f64);
            let store = ResidentStore::from_mats(None, y, chunk).unwrap();
            let a = Partition::from_manifest(store.manifest(), workers).unwrap();
            let b = Partition::new(n, chunk, workers);
            assert_eq!((a.n, a.chunk), (b.n, b.chunk));
            assert_eq!(a.per_worker, b.per_worker, "n={n} chunk={chunk} w={workers}");
        });
    }

    #[test]
    fn manifest_partition_rejects_corruption() {
        use crate::data::store::{ChunkSource, ResidentStore};
        use crate::linalg::Mat;
        let y = Mat::from_fn(20, 1, |i, _| i as f64);
        let store = ResidentStore::from_mats(None, y, 8).unwrap();
        assert!(Partition::from_manifest(store.manifest(), 0).is_err());

        // NaN summary stats must be caught before assignment.
        let mut bad = store.manifest().clone();
        bad.chunks[1].y_cols[0].mean = f64::NAN;
        assert!(Partition::from_manifest(&bad, 2).is_err());

        // A row count that breaks Σ rows == n likewise.
        let mut bad = store.manifest().clone();
        bad.chunks[2].rows = 1;
        assert!(Partition::from_manifest(&bad, 2).is_err());
    }
}
