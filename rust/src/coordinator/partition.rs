//! Data partitioning: N datapoints -> fixed-size chunks -> workers.
//!
//! Chunks are fixed-shape (the AOT artifacts are compiled for a static
//! chunk size C); the ragged tail is padded and masked with w ∈ {0,1}.
//! Workers receive *contiguous* runs of chunks so their local parameter
//! slices (μ, S rows) are contiguous ranges of the global matrices.

/// A contiguous run of datapoint indices `[start, end)`, `end − start ≤ C`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkRange {
    /// First datapoint index (inclusive).
    pub start: usize,
    /// One past the last datapoint index.
    pub end: usize,
}

impl ChunkRange {
    /// Number of datapoints in the range.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Is the range empty?
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// The full assignment of chunks to workers.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Total datapoint count.
    pub n: usize,
    /// Fixed chunk size C (the last chunk may be shorter).
    pub chunk: usize,
    /// `per_worker[r]` = the chunks owned by rank r (contiguous run).
    pub per_worker: Vec<Vec<ChunkRange>>,
}

impl Partition {
    /// Split `n` datapoints into `⌈n/chunk⌉` chunks and deal them out to
    /// `workers` ranks in contiguous, balanced runs.
    pub fn new(n: usize, chunk: usize, workers: usize) -> Partition {
        assert!(chunk > 0 && workers > 0 && n > 0);
        let chunks: Vec<ChunkRange> = (0..n)
            .step_by(chunk)
            .map(|s| ChunkRange { start: s, end: (s + chunk).min(n) })
            .collect();
        let k = chunks.len();
        let mut per_worker = vec![Vec::new(); workers];
        // balanced contiguous split: first (k % workers) ranks get one extra
        let base = k / workers;
        let extra = k % workers;
        let mut idx = 0;
        for (r, bucket) in per_worker.iter_mut().enumerate() {
            let take = base + usize::from(r < extra);
            for _ in 0..take {
                bucket.push(chunks[idx]);
                idx += 1;
            }
        }
        Partition { n, chunk, per_worker }
    }

    /// The contiguous datapoint range owned by rank r (for local-parameter
    /// slicing); `None` if the rank holds no chunks.
    pub fn worker_span(&self, r: usize) -> Option<ChunkRange> {
        let c = &self.per_worker[r];
        if c.is_empty() {
            None
        } else {
            Some(ChunkRange { start: c[0].start, end: c[c.len() - 1].end })
        }
    }

    /// Number of ranks the chunks are dealt across.
    pub fn workers(&self) -> usize {
        self.per_worker.len()
    }

    /// Total number of chunks.
    pub fn num_chunks(&self) -> usize {
        self.per_worker.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn prop_exact_cover() {
        // Every datapoint appears in exactly one chunk of one worker.
        Prop::new("partition_cover").cases(60).run(|rng| {
            let n = 1 + (rng.next_u64() % 500) as usize;
            let chunk = 1 + (rng.next_u64() % 64) as usize;
            let workers = 1 + (rng.next_u64() % 9) as usize;
            let p = Partition::new(n, chunk, workers);
            let mut seen = vec![0u32; n];
            for bucket in &p.per_worker {
                for c in bucket {
                    assert!(c.len() <= chunk);
                    assert!(c.len() > 0);
                    for i in c.start..c.end {
                        seen[i] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&s| s == 1), "n={n} chunk={chunk} w={workers}");
        });
    }

    #[test]
    fn prop_spans_are_contiguous_and_ordered() {
        Prop::new("partition_spans").cases(40).run(|rng| {
            let n = 1 + (rng.next_u64() % 300) as usize;
            let chunk = 1 + (rng.next_u64() % 50) as usize;
            let workers = 1 + (rng.next_u64() % 6) as usize;
            let p = Partition::new(n, chunk, workers);
            let mut cursor = 0;
            for r in 0..workers {
                if let Some(span) = p.worker_span(r) {
                    assert_eq!(span.start, cursor, "gap before rank {r}");
                    cursor = span.end;
                    // chunks within the worker are contiguous too
                    let mut c2 = span.start;
                    for c in &p.per_worker[r] {
                        assert_eq!(c.start, c2);
                        c2 = c.end;
                    }
                }
            }
            assert_eq!(cursor, n);
        });
    }

    #[test]
    fn balance_within_one_chunk() {
        let p = Partition::new(1000, 10, 7); // 100 chunks over 7 workers
        let counts: Vec<usize> = p.per_worker.iter().map(Vec::len).collect();
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(max - min <= 1, "{counts:?}");
    }

    #[test]
    fn more_workers_than_chunks() {
        let p = Partition::new(10, 10, 4); // 1 chunk, 4 workers
        assert_eq!(p.num_chunks(), 1);
        assert!(p.worker_span(0).is_some());
        assert!(p.worker_span(3).is_none());
    }
}
