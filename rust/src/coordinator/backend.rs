//! Worker compute backends: who evaluates the per-chunk statistics.
//!
//! - `RustCpuBackend`     — scalar Rust loops; the per-core "CPU node" of
//!   the paper's Fig 1a.
//! - `ParallelCpuBackend` — the same loops fanned across scoped threads
//!   *within* a rank (the paper's "multicore node"): the chunk list is
//!   split into contiguous slices, one per thread, and the per-chunk
//!   results are re-assembled in chunk order, so the statistics are
//!   **bit-identical** to `RustCpuBackend`.
//! - `XlaBackend`         — the AOT Pallas/JAX artifact on a per-worker
//!   PJRT client; the "GPU card" of Fig 1a (requires the `xla` feature).
//!
//! All backends produce identical statistics/gradients (cross-checked in
//! `rust/tests/xla_vs_rust.rs` and `rust/tests/exec_layer_test.rs`); they
//! differ only in speed. Construction goes through [`make_backends`], the
//! factory keyed by [`BackendKind`] — the evaluation cycle never matches
//! on the kind itself.
//!
//! ## The `FwdCache` contract
//!
//! The batch API threads an opaque per-chunk [`FwdCache`] from
//! `stats_fwd_batch` to the matching `stats_vjp_batch` call (same tasks,
//! same order) so the VJP can reuse what the forward pass already
//! computed (today: the chunk's Ψ1 / K_fu matrix). The contract is
//! **accept-and-ignore**: an empty cache is always valid, a backend with
//! nothing to carry host-side returns `FwdCache::default()`, and a VJP
//! handed an empty/missing cache recomputes — so caching can never
//! change results, only skip work. [`Backend::predict_batch`] follows
//! the same philosophy for serving: backends without a prediction
//! kernel (the XLA artifact set has none) accept the call and run the
//! shared host fallback.

use crate::config::BackendKind;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::predict::PosteriorCore;
use crate::math::stats::{self, ChunkGrads, Stats, StatsCts};
use crate::runtime::{Arg, Executable, Runtime};
use anyhow::{Context, Result};
use std::path::Path;
use std::rc::Rc;

/// A fixed-shape chunk of worker-owned data: `C` rows of Y (padded) and
/// the padding mask. For supervised problems `x` carries the observed
/// inputs (padded); for unsupervised ones it is empty and μ/S arrive from
/// the leader every evaluation.
#[derive(Clone, Debug)]
pub struct ChunkData {
    /// Global index of the first live row.
    pub start: usize,
    /// Number of live rows (≤ C).
    pub live: usize,
    /// C × D, padded with zero rows.
    pub y: Mat,
    /// C × Q observed inputs (supervised) — zero-size otherwise.
    pub x: Mat,
    /// C-length {0,1} mask.
    pub w: Vec<f64>,
}

/// Per-view parameters as broadcast each evaluation.
pub struct ViewParams<'a> {
    /// Inducing inputs, M × Q.
    pub z: &'a Mat,
    /// Kernel hyperparameters as `[log σ², log ℓ_1, …]`.
    pub log_hyp: &'a [f64],
}

/// One chunk's full input for a batch call: the rank's resident chunk
/// (with its Y tile attached) plus its per-evaluation (μ, S) slice for
/// unsupervised models (padded to C rows; S padded with 1.0), or `None`
/// for supervised ones. Both parts are borrowed — static data is never
/// copied on the evaluation hot path, and the (μ, S) slices live in the
/// evaluator's reusable per-chunk buffers (refreshed in place each
/// cycle) rather than being allocated per call.
pub struct ChunkTask<'a> {
    /// The rank-resident chunk (mask, Y tile, supervised x).
    pub chunk: &'a ChunkData,
    /// The chunk's (μ, S) slice for variational problems; `None` for
    /// supervised ones.
    pub latent: Option<(&'a Mat, &'a Mat)>,
}

impl ChunkTask<'_> {
    /// The chunk's (μ, S) slice, reborrowed at the local lifetime.
    pub fn latent(&self) -> Option<(&Mat, &Mat)> {
        self.latent
    }
}

/// Opaque per-chunk state the forward pass computes and the matching VJP
/// pass can reuse — today the chunk's Ψ1 matrix (K_fu for supervised
/// chunks), which both passes otherwise derive from scratch. An empty
/// cache is always valid: backends with nothing to carry host-side (the
/// device-resident XLA path) return `FwdCache::default()` and the VJP
/// recomputes exactly as before.
#[derive(Clone, Debug, Default)]
pub struct FwdCache {
    psi1: Option<Mat>,
}

/// The worker-side compute interface. `latent` is the chunk's (μ, S)
/// slice (padded to C rows; S padded with 1.0) for unsupervised models,
/// or `None` for supervised ones (the chunk's own `x` is used, S ≡ 0).
///
/// The `*_batch` methods evaluate a rank's whole chunk list; the default
/// implementations loop serially, and backends with intra-rank
/// parallelism override them.
pub trait Backend {
    /// One chunk's forward statistics.
    fn stats_fwd(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, include_kl: bool) -> Result<Stats>;

    /// One chunk's VJP under the leader's cotangents.
    fn stats_vjp(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, cts: &StatsCts) -> Result<ChunkGrads>;

    /// Which [`BackendKind`] built this backend.
    fn kind(&self) -> BackendKind;

    /// Forward statistics for every chunk of a rank, in chunk order,
    /// plus one fwd→vjp [`FwdCache`] per chunk (possibly empty).
    ///
    /// The training cycle sums the per-chunk results; the engine's
    /// stats-only pass (the STATS verb behind serving posterior
    /// rebuilds and hot-swaps) instead keeps them separate, packing
    /// each into its global-chunk slot of the reduction wire — both
    /// rely on the **chunk-order** guarantee here, which is what makes
    /// the assembled statistics identical across backends and thread
    /// counts.
    fn stats_fwd_batch(&mut self, tasks: &[ChunkTask], view: &ViewParams,
                       include_kl: bool) -> Result<(Vec<Stats>, Vec<FwdCache>)> {
        let stats = tasks.iter()
            .map(|t| self.stats_fwd(t.chunk, t.latent(), view, include_kl))
            .collect::<Result<Vec<Stats>>>()?;
        let caches = vec![FwdCache::default(); tasks.len()];
        Ok((stats, caches))
    }

    /// VJPs for every chunk of a rank, in chunk order. `caches` is the
    /// per-chunk state the matching `stats_fwd_batch` call returned (same
    /// tasks, same order); missing or empty entries mean "recompute".
    fn stats_vjp_batch(&mut self, tasks: &[ChunkTask], view: &ViewParams,
                       cts: &StatsCts, caches: &[FwdCache]) -> Result<Vec<ChunkGrads>> {
        let _ = caches; // the default path recomputes
        tasks.iter()
            .map(|t| self.stats_vjp(t.chunk, t.latent(), view, cts))
            .collect()
    }

    /// Predictive mean/variance for rows `[row0, row0 + rows)` of
    /// `xstar` against a broadcast [`PosteriorCore`] — the serving
    /// counterpart of the training batch calls. Writes into `mean_out`
    /// (`rows × D`, row-major) and `var_out` (`rows`).
    ///
    /// The default is the core's serial per-row loop.
    /// [`ParallelCpuBackend`] overrides it to fan contiguous row blocks
    /// across scoped threads (bit-identical — the per-row arithmetic is
    /// untouched and rows are independent). The XLA backend has no
    /// prediction artifact, so it accepts the call and takes this host
    /// fallback — the `FwdCache`-style accept-and-ignore contract.
    fn predict_batch(&mut self, core: &PosteriorCore, xstar: &Mat, row0: usize,
                     rows: usize, mean_out: &mut [f64], var_out: &mut [f64])
                     -> Result<()> {
        core.predict_rows_into(xstar, row0, rows, mean_out, var_out);
        Ok(())
    }
}

/// One chunk's forward statistics + fwd→vjp cache on the scalar Rust
/// path (shared by the serial and parallel CPU backends).
fn cpu_fwd_one(task: &ChunkTask, view: &ViewParams, include_kl: bool)
               -> Result<(Stats, FwdCache)> {
    let kern = RbfArd::from_log_hyp(view.log_hyp);
    let chunk = task.chunk;
    let (mut st, psi1) = match task.latent() {
        Some((mu, s)) => {
            stats::bgplvm_stats_fwd_cached(&kern, mu, s, &chunk.w, &chunk.y, view.z)
        }
        None => stats::sgpr_stats_fwd_cached(&kern, &chunk.x, &chunk.w, &chunk.y, view.z),
    };
    if !include_kl {
        st.kl = 0.0;
    }
    Ok((st, FwdCache { psi1: Some(psi1) }))
}

/// One chunk's VJP on the scalar Rust path, reusing the cached Ψ1/K_fu
/// when present.
fn cpu_vjp_one(task: &ChunkTask, view: &ViewParams, cts: &StatsCts,
               cache: Option<&FwdCache>) -> Result<ChunkGrads> {
    let kern = RbfArd::from_log_hyp(view.log_hyp);
    let chunk = task.chunk;
    let psi1 = cache.and_then(|c| c.psi1.as_ref());
    Ok(match task.latent() {
        Some((mu, s)) => stats::bgplvm_stats_vjp_cached(&kern, mu, s, &chunk.w, &chunk.y,
                                                        view.z, cts, psi1),
        None => stats::sgpr_stats_vjp_cached(&kern, &chunk.x, &chunk.w, &chunk.y,
                                             view.z, cts, psi1),
    })
}

/// Factory: one backend per view for `kind`. The returned `Runtime` (if
/// any) owns the PJRT client the `XlaBackend`s execute on and must stay
/// alive as long as they do.
pub fn make_backends(kind: BackendKind, aot_configs: &[String], artifacts_dir: &Path)
                     -> Result<(Vec<Box<dyn Backend>>, Option<Runtime>)> {
    let mut backends: Vec<Box<dyn Backend>> = Vec::with_capacity(aot_configs.len());
    match kind {
        BackendKind::RustCpu => {
            for _ in aot_configs {
                backends.push(Box::new(RustCpuBackend));
            }
            Ok((backends, None))
        }
        BackendKind::ParallelCpu { threads } => {
            for _ in aot_configs {
                backends.push(Box::new(ParallelCpuBackend::new(threads)));
            }
            Ok((backends, None))
        }
        BackendKind::Xla => {
            let rt = Runtime::new(artifacts_dir)?;
            for config in aot_configs {
                backends.push(Box::new(XlaBackend::new(&rt, config)?));
            }
            Ok((backends, Some(rt)))
        }
    }
}

// ---------------------------------------------------------------------
// Rust CPU backend
// ---------------------------------------------------------------------

/// Scalar Rust implementation (math::stats + kern).
pub struct RustCpuBackend;

impl Backend for RustCpuBackend {
    fn stats_fwd(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, include_kl: bool) -> Result<Stats> {
        let kern = RbfArd::from_log_hyp(view.log_hyp);
        let mut st = match latent {
            Some((mu, s)) => stats::bgplvm_stats_fwd(&kern, mu, s, &chunk.w, &chunk.y, view.z),
            None => stats::sgpr_stats_fwd(&kern, &chunk.x, &chunk.w, &chunk.y, view.z),
        };
        if !include_kl {
            st.kl = 0.0;
        }
        Ok(st)
    }

    fn stats_vjp(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, cts: &StatsCts) -> Result<ChunkGrads> {
        let kern = RbfArd::from_log_hyp(view.log_hyp);
        Ok(match latent {
            Some((mu, s)) => stats::bgplvm_stats_vjp(&kern, mu, s, &chunk.w, &chunk.y,
                                                     view.z, cts),
            None => stats::sgpr_stats_vjp(&kern, &chunk.x, &chunk.w, &chunk.y, view.z, cts),
        })
    }

    fn kind(&self) -> BackendKind {
        BackendKind::RustCpu
    }

    fn stats_fwd_batch(&mut self, tasks: &[ChunkTask], view: &ViewParams,
                       include_kl: bool) -> Result<(Vec<Stats>, Vec<FwdCache>)> {
        let mut stats = Vec::with_capacity(tasks.len());
        let mut caches = Vec::with_capacity(tasks.len());
        for t in tasks {
            let (st, cache) = cpu_fwd_one(t, view, include_kl)?;
            stats.push(st);
            caches.push(cache);
        }
        Ok((stats, caches))
    }

    fn stats_vjp_batch(&mut self, tasks: &[ChunkTask], view: &ViewParams,
                       cts: &StatsCts, caches: &[FwdCache]) -> Result<Vec<ChunkGrads>> {
        tasks.iter()
            .enumerate()
            .map(|(i, t)| cpu_vjp_one(t, view, cts, caches.get(i)))
            .collect()
    }
}

// ---------------------------------------------------------------------
// parallel CPU backend
// ---------------------------------------------------------------------

/// Intra-rank chunk parallelism: the batch calls fan a rank's chunk list
/// across scoped OS threads, each running the scalar `RustCpuBackend`
/// math on a contiguous slice. Per-chunk results are concatenated in
/// spawn (= chunk) order and per-chunk computation is untouched, so the
/// output is bit-identical to the serial backend — the engine's
/// chunk-order accumulation then produces bit-identical `Stats` and
/// `ChunkGrads` too (asserted in `tests/exec_layer_test.rs`).
pub struct ParallelCpuBackend {
    /// Worker threads for batch calls; 0 = one per available core.
    threads: usize,
}

impl ParallelCpuBackend {
    /// Build with a fixed thread count; 0 = one per available core.
    pub fn new(threads: usize) -> ParallelCpuBackend {
        ParallelCpuBackend { threads }
    }

    /// Threads actually used for a batch of `tasks` chunks.
    fn fan_out(&self, tasks: usize) -> usize {
        let configured = if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        configured.max(1).min(tasks.max(1))
    }

    /// Split `tasks` across threads and apply `f` to each chunk (called
    /// with the chunk's batch index, so callers can line up per-chunk
    /// side state like the fwd→vjp caches), returning results in chunk
    /// order.
    fn run_batch<T: Send>(
        &self,
        tasks: &[ChunkTask],
        f: impl Fn(usize, &ChunkTask) -> Result<T> + Sync,
    ) -> Result<Vec<T>> {
        let threads = self.fan_out(tasks.len());
        if threads <= 1 || tasks.len() <= 1 {
            return tasks.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let per = tasks.len().saturating_add(threads - 1) / threads;
        let f = &f;
        let per_thread: Result<Vec<Vec<T>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = tasks
                .chunks(per)
                .enumerate()
                .map(|(slice_idx, slice)| {
                    scope.spawn(move || {
                        slice.iter()
                            .enumerate()
                            .map(|(i, t)| f(slice_idx * per + i, t))
                            .collect::<Result<Vec<T>>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("parallel-cpu worker panicked"))
                .collect()
        });
        Ok(per_thread?.into_iter().flatten().collect())
    }
}

impl Backend for ParallelCpuBackend {
    fn stats_fwd(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, include_kl: bool) -> Result<Stats> {
        RustCpuBackend.stats_fwd(chunk, latent, view, include_kl)
    }

    fn stats_vjp(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, cts: &StatsCts) -> Result<ChunkGrads> {
        RustCpuBackend.stats_vjp(chunk, latent, view, cts)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::ParallelCpu { threads: self.threads }
    }

    fn stats_fwd_batch(&mut self, tasks: &[ChunkTask], view: &ViewParams,
                       include_kl: bool) -> Result<(Vec<Stats>, Vec<FwdCache>)> {
        let pairs = self.run_batch(tasks, |_, t| cpu_fwd_one(t, view, include_kl))?;
        Ok(pairs.into_iter().unzip())
    }

    fn stats_vjp_batch(&mut self, tasks: &[ChunkTask], view: &ViewParams,
                       cts: &StatsCts, caches: &[FwdCache]) -> Result<Vec<ChunkGrads>> {
        self.run_batch(tasks, |i, t| cpu_vjp_one(t, view, cts, caches.get(i)))
    }

    /// Row-block fan-out: contiguous blocks of prediction rows go to
    /// scoped threads, each writing a disjoint slice of the output
    /// buffers. Per-row arithmetic is the shared core loop, so the
    /// result is bit-identical to the serial default.
    fn predict_batch(&mut self, core: &PosteriorCore, xstar: &Mat, row0: usize,
                     rows: usize, mean_out: &mut [f64], var_out: &mut [f64])
                     -> Result<()> {
        let d = core.d();
        let threads = self.fan_out(rows);
        if threads <= 1 || rows <= 1 || d == 0 {
            core.predict_rows_into(xstar, row0, rows, mean_out, var_out);
            return Ok(());
        }
        let per = rows.saturating_add(threads - 1) / threads;
        std::thread::scope(|scope| {
            for (b, (mblock, vblock)) in mean_out
                .chunks_mut(per * d)
                .zip(var_out.chunks_mut(per))
                .enumerate()
            {
                scope.spawn(move || {
                    core.predict_rows_into(xstar, row0 + b * per, vblock.len(),
                                           mblock, vblock);
                });
            }
        });
        Ok(())
    }
}

// ---------------------------------------------------------------------
// XLA backend
// ---------------------------------------------------------------------

/// AOT-artifact execution on a per-worker PJRT client. One backend holds
/// the four stats executables for one AOT config (one view); multi-view
/// engines hold one `XlaBackend` per view.
pub struct XlaBackend {
    bgplvm_fwd: Rc<Executable>,
    bgplvm_vjp: Rc<Executable>,
    sgpr_fwd: Rc<Executable>,
    sgpr_vjp: Rc<Executable>,
    m: usize,
    d: usize,
}

impl XlaBackend {
    /// Compile (or fetch from the runtime's cache) the stats modules of
    /// `config`.
    pub fn new(rt: &Runtime, config: &str) -> Result<XlaBackend> {
        let bgplvm_fwd = rt.module(config, "bgplvm_fwd")?;
        let dims = bgplvm_fwd.spec().dims;
        Ok(XlaBackend {
            bgplvm_fwd,
            bgplvm_vjp: rt.module(config, "bgplvm_vjp")?,
            sgpr_fwd: rt.module(config, "sgpr_fwd")?,
            sgpr_vjp: rt.module(config, "sgpr_vjp")?,
            m: dims.m,
            d: dims.d,
        })
    }

    /// Convenience: build a runtime + backend in one go.
    pub fn from_dir(artifacts_dir: &Path, config: &str) -> Result<(Runtime, XlaBackend)> {
        let rt = Runtime::new(artifacts_dir)?;
        let be = XlaBackend::new(&rt, config)?;
        Ok((rt, be))
    }
}

impl Backend for XlaBackend {
    fn stats_fwd(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, include_kl: bool) -> Result<Stats> {
        let (m, d) = (self.m, self.d);
        let out = match latent {
            Some((mu, s)) => self.bgplvm_fwd.call(&[
                Arg::Buf(mu.as_slice()), Arg::Buf(s.as_slice()), Arg::Buf(&chunk.w),
                Arg::Buf(chunk.y.as_slice()), Arg::Buf(view.z.as_slice()),
                Arg::Buf(view.log_hyp),
            ]).context("bgplvm_fwd")?,
            None => self.sgpr_fwd.call(&[
                Arg::Buf(chunk.x.as_slice()), Arg::Buf(&chunk.w),
                Arg::Buf(chunk.y.as_slice()), Arg::Buf(view.z.as_slice()),
                Arg::Buf(view.log_hyp),
            ]).context("sgpr_fwd")?,
        };
        let kl = if latent.is_some() && include_kl { out[4][0] } else { 0.0 };
        Ok(Stats {
            psi0: out[0][0],
            p: Mat::from_vec(m, d, out[1].clone()),
            psi2: Mat::from_vec(m, m, out[2].clone()),
            tryy: out[3][0],
            kl,
            n_eff: chunk.w.iter().sum(),
        })
    }

    fn stats_vjp(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, cts: &StatsCts) -> Result<ChunkGrads> {
        let q = view.z.cols();
        match latent {
            Some((mu, s)) => {
                let out = self.bgplvm_vjp.call(&[
                    Arg::Buf(mu.as_slice()), Arg::Buf(s.as_slice()), Arg::Buf(&chunk.w),
                    Arg::Buf(chunk.y.as_slice()), Arg::Buf(view.z.as_slice()),
                    Arg::Buf(view.log_hyp),
                    Arg::Scalar(cts.c_psi0), Arg::Buf(cts.c_p.as_slice()),
                    Arg::Buf(cts.c_psi2.as_slice()), Arg::Scalar(cts.c_tryy),
                    Arg::Scalar(cts.c_kl),
                ]).context("bgplvm_vjp")?;
                let c = mu.rows();
                Ok(ChunkGrads {
                    dmu: Mat::from_vec(c, q, out[0].clone()),
                    ds: Mat::from_vec(c, q, out[1].clone()),
                    dz: Mat::from_vec(self.m, q, out[2].clone()),
                    dhyp: out[3].clone(),
                })
            }
            None => {
                let out = self.sgpr_vjp.call(&[
                    Arg::Buf(chunk.x.as_slice()), Arg::Buf(&chunk.w),
                    Arg::Buf(chunk.y.as_slice()), Arg::Buf(view.z.as_slice()),
                    Arg::Buf(view.log_hyp),
                    Arg::Scalar(cts.c_psi0), Arg::Buf(cts.c_p.as_slice()),
                    Arg::Buf(cts.c_psi2.as_slice()), Arg::Scalar(cts.c_tryy),
                ]).context("sgpr_vjp")?;
                Ok(ChunkGrads {
                    dmu: Mat::zeros(0, 0),
                    ds: Mat::zeros(0, 0),
                    dz: Mat::from_vec(self.m, q, out[0].clone()),
                    dhyp: out[1].clone(),
                })
            }
        }
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Xla
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Rng64;

    fn chunk(rng: &mut Rng64, c: usize, d: usize, start: usize) -> ChunkData {
        let live = c - 2;
        let mut w = vec![0.0; c];
        w[..live].fill(1.0);
        ChunkData {
            start,
            live,
            y: Mat::from_fn(c, d, |_, _| rng.normal()),
            x: Mat::zeros(0, 0),
            w,
        }
    }

    /// The parallel backend must reproduce the serial backend's per-chunk
    /// outputs exactly, for thread counts that do and don't divide the
    /// chunk count.
    #[test]
    fn parallel_batch_bit_identical_to_serial() {
        let (c, q, d, m) = (16, 2, 3, 5);
        let mut rng = Rng64::new(77);
        let chunks: Vec<ChunkData> =
            (0..7).map(|i| chunk(&mut rng, c, d, i * c)).collect();
        let latents: Vec<(Mat, Mat)> = (0..chunks.len())
            .map(|_| (Mat::from_fn(c, q, |_, _| rng.normal()),
                      Mat::from_fn(c, q, |_, _| rng.uniform_range(0.2, 1.2))))
            .collect();
        let tasks: Vec<ChunkTask> = chunks
            .iter()
            .zip(&latents)
            .map(|(ch, (mu, s))| ChunkTask { chunk: ch, latent: Some((mu, s)) })
            .collect();
        let z = Mat::from_fn(m, q, |_, _| rng.normal());
        let log_hyp = RbfArd::iso(1.2, 0.8, q).to_log_hyp();
        let vp = ViewParams { z: &z, log_hyp: &log_hyp };

        let (serial, serial_caches) =
            RustCpuBackend.stats_fwd_batch(&tasks, &vp, true).unwrap();
        assert_eq!(serial_caches.len(), tasks.len());
        for threads in [1, 2, 3, 7, 16] {
            let (par, _) = ParallelCpuBackend::new(threads)
                .stats_fwd_batch(&tasks, &vp, true)
                .unwrap();
            assert_eq!(par.len(), serial.len());
            for (a, b) in par.iter().zip(&serial) {
                assert!(a.psi0 == b.psi0 && a.tryy == b.tryy && a.kl == b.kl,
                        "threads={threads}: scalar stats differ");
                assert!(a.p.max_abs_diff(&b.p) == 0.0, "threads={threads}: P differs");
                assert!(a.psi2.max_abs_diff(&b.psi2) == 0.0,
                        "threads={threads}: Psi2 differs");
            }
        }

        let cts = StatsCts {
            c_psi0: 0.4,
            c_p: Mat::from_fn(m, d, |_, _| rng.normal()),
            c_psi2: Mat::from_fn(m, m, |_, _| rng.normal()),
            c_tryy: -0.2,
            c_kl: -1.0,
        };
        let serial = RustCpuBackend
            .stats_vjp_batch(&tasks, &vp, &cts, &serial_caches).unwrap();
        // cache hit and cache miss must be bit-identical on the
        // variational path (same Ψ1 bits either way)
        let uncached = RustCpuBackend.stats_vjp_batch(&tasks, &vp, &cts, &[]).unwrap();
        let (_, par_caches) =
            ParallelCpuBackend::new(3).stats_fwd_batch(&tasks, &vp, true).unwrap();
        let par = ParallelCpuBackend::new(3)
            .stats_vjp_batch(&tasks, &vp, &cts, &par_caches).unwrap();
        for ((a, b), u) in par.iter().zip(&serial).zip(&uncached) {
            assert!(a.dmu.max_abs_diff(&b.dmu) == 0.0);
            assert!(a.ds.max_abs_diff(&b.ds) == 0.0);
            assert!(a.dz.max_abs_diff(&b.dz) == 0.0);
            assert_eq!(a.dhyp, b.dhyp);
            assert!(u.dmu.max_abs_diff(&b.dmu) == 0.0, "cache changed the VJP");
            assert!(u.dz.max_abs_diff(&b.dz) == 0.0, "cache changed the VJP");
        }
    }

    /// `predict_batch` on the parallel backend must reproduce the serial
    /// default bit for bit, for thread counts that do and don't divide
    /// the row count, and for offset row ranges.
    #[test]
    fn parallel_predict_batch_bit_identical_to_serial() {
        use crate::math::predict::PosteriorCore;
        use crate::math::stats::sgpr_stats_fwd;

        let (n, m, q, d) = (40usize, 9usize, 2usize, 3usize);
        let mut rng = Rng64::new(123);
        let x = Mat::from_fn(n, q, |_, _| rng.normal());
        let y = Mat::from_fn(n, d, |_, _| rng.normal());
        let z = Mat::from_fn(m, q, |_, _| rng.normal());
        let kern = RbfArd::iso(1.1, 0.9, q);
        let w = vec![1.0; n];
        let st = sgpr_stats_fwd(&kern, &x, &w, &y, &z);
        let core = PosteriorCore::new(kern, z, 30.0, &st).unwrap();

        let nt = 23;
        let xstar = Mat::from_fn(nt, q, |_, _| rng.normal());
        for (row0, rows) in [(0usize, nt), (5, 11), (22, 1)] {
            let mut mean_s = vec![0.0; rows * d];
            let mut var_s = vec![0.0; rows];
            RustCpuBackend
                .predict_batch(&core, &xstar, row0, rows, &mut mean_s, &mut var_s)
                .unwrap();
            for threads in [1usize, 2, 3, 7, 32] {
                let mut mean_p = vec![0.0; rows * d];
                let mut var_p = vec![0.0; rows];
                ParallelCpuBackend::new(threads)
                    .predict_batch(&core, &xstar, row0, rows, &mut mean_p, &mut var_p)
                    .unwrap();
                assert_eq!(mean_p, mean_s, "threads={threads} rows={row0}+{rows}");
                assert_eq!(var_p, var_s, "threads={threads} rows={row0}+{rows}");
            }
        }
    }

    #[test]
    fn factory_builds_cpu_kinds() {
        let configs = vec!["a".to_string(), "b".to_string()];
        let (b, rt) = make_backends(BackendKind::RustCpu, &configs, Path::new(".")).unwrap();
        assert_eq!(b.len(), 2);
        assert!(rt.is_none());
        assert_eq!(b[0].kind(), BackendKind::RustCpu);

        let (b, rt) = make_backends(BackendKind::ParallelCpu { threads: 2 }, &configs,
                                    Path::new(".")).unwrap();
        assert_eq!(b.len(), 2);
        assert!(rt.is_none());
        assert_eq!(b[0].kind(), BackendKind::ParallelCpu { threads: 2 });
    }
}
