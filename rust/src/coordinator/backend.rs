//! Worker compute backends: who evaluates the per-chunk statistics.
//!
//! - `RustCpuBackend` — scalar Rust loops; the per-core "CPU node" of the
//!   paper's Fig 1a.
//! - `XlaBackend`     — the AOT Pallas/JAX artifact on a per-worker PJRT
//!   client; the "GPU card" of Fig 1a.
//!
//! Both produce identical statistics/gradients (cross-checked in
//! `rust/tests/xla_vs_rust.rs`); they differ only in speed.

use crate::config::BackendKind;
use crate::kern::RbfArd;
use crate::linalg::Mat;
use crate::math::stats::{self, ChunkGrads, Stats, StatsCts};
use crate::runtime::{Arg, Executable, Runtime};
use anyhow::{Context, Result};
use std::path::Path;
use std::rc::Rc;

/// A fixed-shape chunk of worker-owned data: `C` rows of Y (padded) and
/// the padding mask. For supervised problems `x` carries the observed
/// inputs (padded); for unsupervised ones it is empty and μ/S arrive from
/// the leader every evaluation.
#[derive(Clone, Debug)]
pub struct ChunkData {
    /// Global index of the first live row.
    pub start: usize,
    /// Number of live rows (≤ C).
    pub live: usize,
    /// C × D, padded with zero rows.
    pub y: Mat,
    /// C × Q observed inputs (supervised) — zero-size otherwise.
    pub x: Mat,
    /// C-length {0,1} mask.
    pub w: Vec<f64>,
}

/// Per-view parameters as broadcast each evaluation.
pub struct ViewParams<'a> {
    pub z: &'a Mat,
    pub log_hyp: &'a [f64],
}

/// The worker-side compute interface. `latent` is the chunk's (μ, S)
/// slice (padded to C rows; S padded with 1.0) for unsupervised models,
/// or `None` for supervised ones (the chunk's own `x` is used, S ≡ 0).
pub trait Backend {
    fn stats_fwd(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, include_kl: bool) -> Result<Stats>;

    fn stats_vjp(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, cts: &StatsCts) -> Result<ChunkGrads>;

    fn kind(&self) -> BackendKind;
}

// ---------------------------------------------------------------------
// Rust CPU backend
// ---------------------------------------------------------------------

/// Scalar Rust implementation (math::stats + kern).
pub struct RustCpuBackend;

impl Backend for RustCpuBackend {
    fn stats_fwd(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, include_kl: bool) -> Result<Stats> {
        let kern = RbfArd::from_log_hyp(view.log_hyp);
        let mut st = match latent {
            Some((mu, s)) => stats::bgplvm_stats_fwd(&kern, mu, s, &chunk.w, &chunk.y, view.z),
            None => stats::sgpr_stats_fwd(&kern, &chunk.x, &chunk.w, &chunk.y, view.z),
        };
        if !include_kl {
            st.kl = 0.0;
        }
        Ok(st)
    }

    fn stats_vjp(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, cts: &StatsCts) -> Result<ChunkGrads> {
        let kern = RbfArd::from_log_hyp(view.log_hyp);
        Ok(match latent {
            Some((mu, s)) => stats::bgplvm_stats_vjp(&kern, mu, s, &chunk.w, &chunk.y,
                                                     view.z, cts),
            None => stats::sgpr_stats_vjp(&kern, &chunk.x, &chunk.w, &chunk.y, view.z, cts),
        })
    }

    fn kind(&self) -> BackendKind {
        BackendKind::RustCpu
    }
}

// ---------------------------------------------------------------------
// XLA backend
// ---------------------------------------------------------------------

/// AOT-artifact execution on a per-worker PJRT client. One backend holds
/// the four stats executables for one AOT config (one view); multi-view
/// engines hold one `XlaBackend` per view.
pub struct XlaBackend {
    bgplvm_fwd: Rc<Executable>,
    bgplvm_vjp: Rc<Executable>,
    sgpr_fwd: Rc<Executable>,
    sgpr_vjp: Rc<Executable>,
    m: usize,
    d: usize,
}

impl XlaBackend {
    /// Compile (or fetch from the runtime's cache) the stats modules of
    /// `config`.
    pub fn new(rt: &Runtime, config: &str) -> Result<XlaBackend> {
        let bgplvm_fwd = rt.module(config, "bgplvm_fwd")?;
        let dims = bgplvm_fwd.spec().dims;
        Ok(XlaBackend {
            bgplvm_fwd,
            bgplvm_vjp: rt.module(config, "bgplvm_vjp")?,
            sgpr_fwd: rt.module(config, "sgpr_fwd")?,
            sgpr_vjp: rt.module(config, "sgpr_vjp")?,
            m: dims.m,
            d: dims.d,
        })
    }

    /// Convenience: build a runtime + backend in one go.
    pub fn from_dir(artifacts_dir: &Path, config: &str) -> Result<(Runtime, XlaBackend)> {
        let rt = Runtime::new(artifacts_dir)?;
        let be = XlaBackend::new(&rt, config)?;
        Ok((rt, be))
    }
}

impl Backend for XlaBackend {
    fn stats_fwd(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, include_kl: bool) -> Result<Stats> {
        let (m, d) = (self.m, self.d);
        let out = match latent {
            Some((mu, s)) => self.bgplvm_fwd.call(&[
                Arg::Buf(mu.as_slice()), Arg::Buf(s.as_slice()), Arg::Buf(&chunk.w),
                Arg::Buf(chunk.y.as_slice()), Arg::Buf(view.z.as_slice()),
                Arg::Buf(view.log_hyp),
            ]).context("bgplvm_fwd")?,
            None => self.sgpr_fwd.call(&[
                Arg::Buf(chunk.x.as_slice()), Arg::Buf(&chunk.w),
                Arg::Buf(chunk.y.as_slice()), Arg::Buf(view.z.as_slice()),
                Arg::Buf(view.log_hyp),
            ]).context("sgpr_fwd")?,
        };
        let kl = if latent.is_some() && include_kl { out[4][0] } else { 0.0 };
        Ok(Stats {
            psi0: out[0][0],
            p: Mat::from_vec(m, d, out[1].clone()),
            psi2: Mat::from_vec(m, m, out[2].clone()),
            tryy: out[3][0],
            kl,
            n_eff: chunk.w.iter().sum(),
        })
    }

    fn stats_vjp(&mut self, chunk: &ChunkData, latent: Option<(&Mat, &Mat)>,
                 view: &ViewParams, cts: &StatsCts) -> Result<ChunkGrads> {
        let q = view.z.cols();
        match latent {
            Some((mu, s)) => {
                let out = self.bgplvm_vjp.call(&[
                    Arg::Buf(mu.as_slice()), Arg::Buf(s.as_slice()), Arg::Buf(&chunk.w),
                    Arg::Buf(chunk.y.as_slice()), Arg::Buf(view.z.as_slice()),
                    Arg::Buf(view.log_hyp),
                    Arg::Scalar(cts.c_psi0), Arg::Buf(cts.c_p.as_slice()),
                    Arg::Buf(cts.c_psi2.as_slice()), Arg::Scalar(cts.c_tryy),
                    Arg::Scalar(cts.c_kl),
                ]).context("bgplvm_vjp")?;
                let c = mu.rows();
                Ok(ChunkGrads {
                    dmu: Mat::from_vec(c, q, out[0].clone()),
                    ds: Mat::from_vec(c, q, out[1].clone()),
                    dz: Mat::from_vec(self.m, q, out[2].clone()),
                    dhyp: out[3].clone(),
                })
            }
            None => {
                let out = self.sgpr_vjp.call(&[
                    Arg::Buf(chunk.x.as_slice()), Arg::Buf(&chunk.w),
                    Arg::Buf(chunk.y.as_slice()), Arg::Buf(view.z.as_slice()),
                    Arg::Buf(view.log_hyp),
                    Arg::Scalar(cts.c_psi0), Arg::Buf(cts.c_p.as_slice()),
                    Arg::Buf(cts.c_psi2.as_slice()), Arg::Scalar(cts.c_tryy),
                ]).context("sgpr_vjp")?;
                Ok(ChunkGrads {
                    dmu: Mat::zeros(0, 0),
                    ds: Mat::zeros(0, 0),
                    dz: Mat::from_vec(self.m, q, out[0].clone()),
                    dhyp: out[1].clone(),
                })
            }
        }
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Xla
    }
}
