//! Layer-3 coordinator: the paper's distributed-inference scheme.
//!
//! - `partition` — datapoints -> fixed-shape chunks -> workers
//! - `backend`   — who computes a chunk's statistics, behind the
//!   [`backend::make_backends`] factory: scalar Rust loops, the
//!   multicore `parallel-cpu` fan-out, or the AOT XLA artifact (the
//!   paper's CPU-core vs multicore-node vs GPU-card axis)
//! - `engine`    — the execution layer: `engine::problem` (model
//!   statement + parameter layout), `engine::cycle` (the SPMD
//!   leader/worker evaluation cycle as a reusable
//!   [`DistributedEvaluator`]), `engine::train` (optimiser loop),
//!   `engine::serve` (sharded posterior serving,
//!   [`DistributedPosterior`]), and `engine::frontend` (the
//!   concurrent-client micro-batching scheduler, [`ServingFrontend`]),
//!   with per-phase timing (distributable vs indistributable, feeding
//!   Fig 1b)

pub mod backend;
pub mod engine;
pub mod partition;

pub use backend::{make_backends, Backend, ChunkData, ChunkTask, FwdCache,
                  ParallelCpuBackend, RustCpuBackend, ViewParams, XlaBackend};
pub use engine::{DistributedEvaluator, DistributedPosterior, Engine, EngineConfig, Fitted,
                 FrontendConfig, FrontendHandle, LatentSpec, OptChoice, Problem,
                 ServeSignal, ServingFrontend, ServingReport, TrainResult, ViewData,
                 ViewSpec};
pub use partition::{ChunkRange, Partition};
