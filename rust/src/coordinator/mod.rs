//! Layer-3 coordinator: the paper's distributed-inference scheme.
//!
//! - `partition` — datapoints -> fixed-shape chunks -> workers
//! - `backend`   — who computes a chunk's statistics (Rust loops vs the
//!   AOT XLA artifact; the paper's CPU-core vs GPU-card axis)
//! - `engine`    — the SPMD leader/worker training loop with per-phase
//!   timing (distributable vs indistributable, feeding Fig 1b)

pub mod backend;
pub mod engine;
pub mod partition;

pub use backend::{Backend, ChunkData, RustCpuBackend, ViewParams, XlaBackend};
pub use engine::{Engine, EngineConfig, Fitted, LatentSpec, OptChoice, Problem,
                 TrainResult, ViewSpec};
pub use partition::{ChunkRange, Partition};
