//! Fig 1a reproduction: average time per optimisation iteration of the
//! Bayesian GP-LVM vs dataset size, for several parallel configurations.
//!
//!   cargo bench --bench fig1a_scaling            # full sweep (paper sizes)
//!   FIG1A_FAST=1 cargo bench --bench fig1a_scaling   # CI-sized sweep
//!
//! Paper setup: synthetic RBF data, Q=1, D=3, M=100, N in 1k..64k;
//! configurations {1,4,16,32} CPU cores and {1,2,4} GPUs. Here the CPU
//! core is the scalar Rust backend and the GPU card is the per-worker
//! XLA executable (see DESIGN.md §2). This host is single-core, so the
//! paper's y-axis is reconstructed as the *projected* critical-path time
//! per iteration (max over ranks of distributable compute + leader core),
//! with raw wall-clock printed alongside for honesty.
//!
//! Output: a paper-style table, per-config linearity slopes, the
//! GPU-vs-32-core ratio the paper highlights, and results/fig1a.csv.

use gpparallel::config::BackendKind;
use gpparallel::coordinator::{Engine, EngineConfig, OptChoice};
use gpparallel::data::synthetic::{generate, SyntheticSpec};
use gpparallel::models::BayesianGplvm;
use gpparallel::optim::Lbfgs;
use std::fmt::Write as _;

struct Row {
    backend: BackendKind,
    workers: usize,
    n: usize,
    wall: f64,
    projected: f64,
    indist_frac: f64,
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("FIG1A_FAST").is_ok();
    // default sweep tops out at 16k so `cargo bench` stays ~minutes on
    // this single-core host; FIG1A_HUGE=1 extends to the paper's full 64k.
    let huge = std::env::var("FIG1A_HUGE").is_ok();
    let sizes: Vec<usize> = if fast {
        vec![1024, 2048, 4096]
    } else if huge {
        vec![1024, 2048, 4096, 8192, 16384, 32768, 65536]
    } else {
        vec![1024, 2048, 4096, 8192, 16384]
    };
    // (backend, workers) — paper: {1,4,16,32} CPUs, {1,2,4} GPUs.
    let configs: Vec<(BackendKind, usize)> = if fast {
        vec![(BackendKind::RustCpu, 1), (BackendKind::RustCpu, 4),
             (BackendKind::Xla, 1)]
    } else {
        vec![
            (BackendKind::RustCpu, 1), (BackendKind::RustCpu, 4),
            (BackendKind::RustCpu, 16), (BackendKind::RustCpu, 32),
            // one multicore node: intra-rank fan-out instead of more ranks
            (BackendKind::parallel_auto(), 1),
            (BackendKind::Xla, 1), (BackendKind::Xla, 2), (BackendKind::Xla, 4),
        ]
    };
    let evals = 2;

    println!("Fig 1a — avg time per iteration, BGP-LVM (M=100, Q=1, D=3)");
    println!("{:>9} {:>8} {:>8} {:>13} {:>16} {:>9}",
             "backend", "workers", "N", "wall s/iter", "projected s/iter", "indist %");

    let mut rows: Vec<Row> = Vec::new();
    for &(backend, workers) in &configs {
        for &n in &sizes {
            // every rank needs at least one chunk. The XLA artifact is
            // compiled for C=1024, so device configs skip small N (as the
            // paper's multi-GPU rows effectively do); the Rust backend is
            // shape-free and shrinks the chunk instead.
            let chunk = match backend {
                BackendKind::Xla => 1024,
                BackendKind::RustCpu | BackendKind::ParallelCpu { .. } => {
                    (n / workers).clamp(1, 1024)
                }
            };
            if n / chunk < workers {
                continue;
            }
            let spec = SyntheticSpec { n, q: 1, d: 3, ..Default::default() };
            let ds = generate(&spec, 0);
            let problem = BayesianGplvm::problem(&ds.y(), 1, 100, "paper", 0);
            let cfg = EngineConfig {
                workers,
                chunk,
                backend,
                artifacts_dir: "artifacts".into(),
                opt: OptChoice::Lbfgs(Lbfgs::default()),
                pipeline: true,
                verbose: false,
                simd: None,
            };
            let engine = Engine::new(problem, cfg)?;
            let r = engine.time_iterations(evals)?;
            let row = Row {
                backend,
                workers,
                n,
                wall: r.sec_per_eval,
                projected: r.projected_sec_per_eval(),
                indist_frac: r.timing.indistributable_fraction(),
            };
            println!("{:>9} {:>8} {:>8} {:>13.4} {:>16.4} {:>9.2}",
                     row.backend.name(), row.workers, row.n, row.wall,
                     row.projected, row.indist_frac * 100.0);
            rows.push(row);
        }
    }

    // --- paper-claim checks -------------------------------------------
    println!("\nlinearity in N (projected time): per-config log-log slope");
    for &(backend, workers) in &configs {
        let pts: Vec<(f64, f64)> = rows.iter()
            .filter(|r| r.backend == backend && r.workers == workers)
            .map(|r| ((r.n as f64).ln(), r.projected.ln()))
            .collect();
        if pts.len() >= 2 {
            let slope = fit_slope(&pts);
            println!("  {:>9} x{:<2}: slope = {:.3}  (paper claim: ~1.0)",
                     backend.name(), workers, slope);
        }
    }

    // device vs many-core comparison at the largest common N
    let biggest = rows.iter().map(|r| r.n).max().unwrap_or(0);
    let cpu_best = rows.iter()
        .filter(|r| r.backend == BackendKind::RustCpu && r.n == biggest)
        .map(|r| r.projected)
        .fold(f64::MAX, f64::min);
    let xla1 = rows.iter()
        .find(|r| r.backend == BackendKind::Xla && r.workers == 1 && r.n == biggest)
        .map(|r| r.projected);
    if let Some(x1) = xla1 {
        println!("\nat N={biggest}: 1 device (XLA) = {x1:.4}s vs best many-core CPU = \
                  {cpu_best:.4}s  -> ratio {:.2}x", cpu_best / x1);
        println!("(paper: a single GPU beats the 32-core node)");
    }

    // CSV
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("backend,workers,n,wall_sec_per_iter,projected_sec_per_iter,indist_frac\n");
    for r in &rows {
        let _ = writeln!(csv, "{},{},{},{},{},{}", r.backend.name(), r.workers, r.n,
                         r.wall, r.projected, r.indist_frac);
    }
    std::fs::write("results/fig1a.csv", csv)?;
    println!("\nwrote results/fig1a.csv");
    Ok(())
}

/// Least-squares slope of y on x.
fn fit_slope(pts: &[(f64, f64)]) -> f64 {
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}
