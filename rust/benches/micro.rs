//! Micro + ablation benches (the design-choice studies DESIGN.md lists):
//!
//!   1. psi-statistics kernel: Rust scalar loops vs the XLA artifact,
//!      per chunk (the per-device building block behind Fig 1a).
//!   2. chunk-size ablation at fixed N (padding/dispatch overhead trade).
//!   3. sparse-distributed vs dense O(N³) GP crossover.
//!   4. optimiser ablation: L-BFGS vs SCG vs Adam on the same model.
//!   5. linalg kernels: naive vs cache-blocked matmul, matmul_t vs syrk.
//!
//! Every timed op is also written to `BENCH_micro.json` as
//! `{op, size, ns_per_iter}` records — one snapshot per run, committed
//! alongside perf PRs so the repo's trajectory accumulates
//! machine-readable data over time.
//!
//!   cargo bench --bench micro      (MICRO_FAST=1 for the short version)

use gpparallel::baselines::DenseGp;
use gpparallel::config::{BackendKind, Json};
use gpparallel::coordinator::backend::{Backend, ChunkData, RustCpuBackend, ViewParams,
                                       XlaBackend};
use gpparallel::coordinator::{Engine, EngineConfig, OptChoice};
use gpparallel::data::rng::Rng64;
use gpparallel::data::synthetic::{generate, generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::models::{BayesianGplvm, Mrd};
use gpparallel::optim::{Adam, Lbfgs, Scg};
use std::collections::BTreeMap;
use std::time::Instant;

fn time_it<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// Machine-readable result sink for BENCH_micro.json.
#[derive(Default)]
struct Records(Vec<(String, usize, f64)>);

impl Records {
    /// Record `seconds` per iteration for (op, size).
    fn push(&mut self, op: &str, size: usize, seconds: f64) {
        self.0.push((op.to_string(), size, seconds * 1e9));
    }

    fn write(&self, path: &str) -> std::io::Result<()> {
        let arr: Vec<Json> = self.0.iter()
            .map(|(op, size, ns)| {
                let mut o = BTreeMap::new();
                o.insert("op".to_string(), Json::Str(op.clone()));
                o.insert("size".to_string(), Json::Num(*size as f64));
                o.insert("ns_per_iter".to_string(), Json::Num(*ns));
                Json::Obj(o)
            })
            .collect();
        std::fs::write(path, Json::Arr(arr).to_string_pretty())
    }
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("MICRO_FAST").is_ok();
    let mut rec = Records::default();

    // ---------------------------------------------------------------
    // 1. per-chunk stats: Rust vs XLA (the paper's Table-1 kernel)
    // ---------------------------------------------------------------
    println!("== per-chunk psi statistics (C=1024, M=100, Q=1, D=3) ==");
    let (c, m, q, d) = (1024usize, 100usize, 1usize, 3usize);
    let mut rng = Rng64::new(1);
    let mu = Mat::from_fn(c, q, |_, _| rng.normal());
    let s = Mat::from_fn(c, q, |_, _| rng.uniform_range(0.2, 1.2));
    let y = Mat::from_fn(c, d, |_, _| rng.normal());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let kern = RbfArd::iso(1.0, 1.0, q);
    let log_hyp = kern.to_log_hyp();
    let chunk = ChunkData { start: 0, live: c, y, x: Mat::zeros(0, 0), w: vec![1.0; c] };
    let vp = ViewParams { z: &z, log_hyp: &log_hyp };

    let reps = if fast { 3 } else { 8 };
    let mut cpu = RustCpuBackend;
    let t_cpu_fwd = time_it(reps, || cpu.stats_fwd(&chunk, Some((&mu, &s)), &vp, true).unwrap());
    println!("  rust-cpu  stats_fwd : {:>9.2} ms", t_cpu_fwd * 1e3);
    rec.push("stats_fwd_rust_cpu", c, t_cpu_fwd);

    // The XLA rows need both the artifacts and the PJRT runtime compiled
    // in — with the `xla` feature off the runtime is a stub whose
    // constructor errors, so gate on the feature too instead of aborting.
    let have_artifacts = cfg!(feature = "xla")
        && std::path::Path::new("artifacts/manifest.json").exists();
    if have_artifacts {
        let (rt, mut xla) = XlaBackend::from_dir(std::path::Path::new("artifacts"), "paper")?;
        let _ = &rt;
        let t_xla_fwd = time_it(reps, || xla.stats_fwd(&chunk, Some((&mu, &s)), &vp, true).unwrap());
        println!("  xla       stats_fwd : {:>9.2} ms   ({:.2}x vs rust-cpu)",
                 t_xla_fwd * 1e3, t_cpu_fwd / t_xla_fwd);
        rec.push("stats_fwd_xla", c, t_xla_fwd);

        use gpparallel::math::stats::StatsCts;
        let cts = StatsCts {
            c_psi0: 0.3,
            c_p: Mat::from_fn(m, d, |_, _| 0.01),
            c_psi2: Mat::from_fn(m, m, |_, _| 0.001),
            c_tryy: -0.5,
            c_kl: -1.0,
        };
        let t_cpu_vjp = time_it(reps, || cpu.stats_vjp(&chunk, Some((&mu, &s)), &vp, &cts).unwrap());
        let t_xla_vjp = time_it(reps, || xla.stats_vjp(&chunk, Some((&mu, &s)), &vp, &cts).unwrap());
        println!("  rust-cpu  stats_vjp : {:>9.2} ms", t_cpu_vjp * 1e3);
        println!("  xla       stats_vjp : {:>9.2} ms   ({:.2}x vs rust-cpu)",
                 t_xla_vjp * 1e3, t_cpu_vjp / t_xla_vjp);
        rec.push("stats_vjp_rust_cpu", c, t_cpu_vjp);
        rec.push("stats_vjp_xla", c, t_xla_vjp);
    } else {
        println!("  (artifacts missing; run `make artifacts` for the XLA rows)");
    }

    // ---------------------------------------------------------------
    // 2. chunk-size ablation (fixed N, XLA needs matching config so we
    //    ablate the Rust backend where chunk is free)
    // ---------------------------------------------------------------
    println!("\n== chunk-size ablation (rust-cpu, N=4096, 2 workers) ==");
    let spec = SyntheticSpec { n: 4096, q: 1, d: 3, ..Default::default() };
    let ds = generate(&spec, 0);
    let y_ablate = ds.y();
    for chunk_size in [256usize, 512, 1024, 2048, 4096] {
        let problem = BayesianGplvm::problem(&y_ablate, 1, 100, "paper", 0);
        let cfg = EngineConfig {
            workers: 2,
            chunk: chunk_size,
            backend: BackendKind::RustCpu,
            artifacts_dir: "artifacts".into(),
            opt: OptChoice::Lbfgs(Lbfgs::default()),
            pipeline: true,
            verbose: false,
            simd: None,
        };
        let r = Engine::new(problem, cfg)?.time_iterations(1)?;
        println!("  chunk {:>5}: {:>8.3} s/iter", chunk_size, r.sec_per_eval);
        rec.push("engine_eval_by_chunk", chunk_size, r.sec_per_eval);
    }

    // ---------------------------------------------------------------
    // 3. sparse-distributed vs dense O(N^3) crossover
    // ---------------------------------------------------------------
    println!("\n== sparse (M=16) vs dense GP: one hyperparameter-objective eval ==");
    println!("{:>6} {:>14} {:>14} {:>8}", "N", "sparse s", "dense s", "ratio");
    let sizes = if fast { vec![256, 512] } else { vec![256, 512, 1024, 2048] };
    for n in sizes {
        let spec = SyntheticSpec { n, q: 1, d: 1, ..Default::default() };
        let dsn = generate_supervised(&spec, 3);
        let x = dsn.x().unwrap();
        let yn = dsn.y();
        let kern = RbfArd::iso(1.0, 1.0, 1);

        // sparse: one full distributed objective evaluation
        let problem = gpparallel::coordinator::Problem {
            latent: gpparallel::coordinator::LatentSpec::Observed(x.clone()),
            views: vec![gpparallel::coordinator::ViewSpec {
                y: yn.clone().into(),
                z0: Mat::from_fn(16, 1, |i, _| -2.0 + 4.0 * i as f64 / 15.0),
                kern0: kern.clone(),
                beta0: 10.0,
                aot_config: "quickstart".into(),
            }],
            q: 1,
        };
        let cfg = EngineConfig {
            workers: 1,
            chunk: 256,
            backend: BackendKind::RustCpu,
            artifacts_dir: "artifacts".into(),
            opt: OptChoice::Lbfgs(Lbfgs::default()),
            pipeline: true,
            verbose: false,
            simd: None,
        };
        let t_sparse = Engine::new(problem, cfg)?.time_iterations(1)?.sec_per_eval;

        // dense: one exact-marginal-likelihood-with-gradients evaluation
        let t_dense = time_it(1, || DenseGp::lml_and_grads(&kern, 10.0f64.ln(), &x, &yn).unwrap());
        println!("{:>6} {:>14.4} {:>14.4} {:>8.2}", n, t_sparse, t_dense,
                 t_dense / t_sparse);
        rec.push("engine_eval_sparse", n, t_sparse);
        rec.push("dense_gp_eval", n, t_dense);
    }

    // ---------------------------------------------------------------
    // 4. optimiser ablation
    // ---------------------------------------------------------------
    println!("\n== optimiser ablation (BGP-LVM, N=256, 40-iteration budget) ==");
    let spec = SyntheticSpec { n: 256, q: 2, d: 3, ..Default::default() };
    let ds = generate(&spec, 4);
    let y_opt = ds.y();
    for (name, opt) in [
        ("L-BFGS", OptChoice::Lbfgs(Lbfgs { max_iters: 40, ..Default::default() })),
        ("SCG", OptChoice::Scg(Scg { max_iters: 40, ..Default::default() })),
        ("Adam", OptChoice::Adam(Adam { lr: 5e-2, max_iters: 40, ..Default::default() })),
    ] {
        let problem = BayesianGplvm::problem(&y_opt, 2, 16, "test", 4);
        let cfg = EngineConfig {
            workers: 1,
            chunk: 64,
            backend: BackendKind::RustCpu,
            artifacts_dir: "artifacts".into(),
            opt,
            pipeline: true,
            verbose: false,
            simd: None,
        };
        let r = Engine::new(problem, cfg)?.train()?;
        println!("  {:>7}: bound {:>10.2} -> {:>10.2}  ({} evals)",
                 name, r.trace.first().unwrap(), r.trace.last().unwrap(),
                 r.evaluations);
    }

    // ---------------------------------------------------------------
    // 5. linalg kernels: blocked matmul + syrk vs the naive loops
    // ---------------------------------------------------------------
    println!("\n== linalg: naive vs cache-blocked matmul, matmul_t vs syrk ==");
    println!("{:>6} {:>12} {:>12} {:>8} {:>12} {:>12}",
             "M", "naive ms", "blocked ms", "speedup", "matmul_t ms", "syrk ms");
    let mm_sizes: Vec<usize> = if fast { vec![64, 128, 256] } else { vec![64, 128, 256, 512] };
    let mut rng = Rng64::new(5);
    for mm in mm_sizes {
        let a = Mat::from_fn(mm, mm, |_, _| rng.normal());
        let b = Mat::from_fn(mm, mm, |_, _| rng.normal());
        let reps = if mm <= 128 { 6 } else { 2 };
        let t_naive = time_it(reps, || a.matmul_naive(&b));
        let t_blocked = time_it(reps, || a.matmul_blocked(&b));
        let t_mm_t = time_it(reps, || a.matmul_t(&a));
        let t_syrk = time_it(reps, || a.syrk());
        println!("{:>6} {:>12.3} {:>12.3} {:>8.2} {:>12.3} {:>12.3}",
                 mm, t_naive * 1e3, t_blocked * 1e3, t_naive / t_blocked,
                 t_mm_t * 1e3, t_syrk * 1e3);
        rec.push("matmul_naive", mm, t_naive);
        rec.push("matmul_blocked", mm, t_blocked);
        rec.push("matmul_t", mm, t_mm_t);
        rec.push("syrk", mm, t_syrk);
    }

    // ---------------------------------------------------------------
    // 6. full distributed cycle: pipelined vs synchronous eval
    //    (ranks × views sweep — the cycle-level perf trajectory)
    // ---------------------------------------------------------------
    println!("\n== full cycle: pipelined vs synchronous eval (ranks x views) ==");
    println!("{:>6} {:>6} {:>6} {:>14} {:>14} {:>8}",
             "N", "ranks", "views", "sync s/iter", "pipe s/iter", "speedup");
    let n_cycle = if fast { 512 } else { 2048 };
    let cycle_evals = if fast { 1 } else { 2 };
    for views in [1usize, 2] {
        for workers in [1usize, 2, 4] {
            let spec = SyntheticSpec { n: n_cycle, q: 1, d: 3, ..Default::default() };
            let problem = if views == 1 {
                BayesianGplvm::problem(&generate(&spec, 6).y(), 1, 50, "paper", 6)
            } else {
                let y1 = generate(&spec, 7).y();
                let y2 = generate(&spec, 8).y();
                Mrd::problem(&[y1, y2], 1, 50, &["paper", "paper"], 7)
            };
            let mut times = [0.0f64; 2];
            for (i, pipeline) in [(0usize, false), (1, true)] {
                let cfg = EngineConfig {
                    workers,
                    chunk: 256,
                    backend: BackendKind::RustCpu,
                    artifacts_dir: "artifacts".into(),
                    opt: OptChoice::Lbfgs(Lbfgs::default()),
                    pipeline,
                    verbose: false,
                    simd: None,
                };
                let r = Engine::new(problem.clone(), cfg)?.time_iterations(cycle_evals)?;
                times[i] = r.sec_per_eval;
                let label = if pipeline { "pipelined" } else { "sync" };
                rec.push(&format!("cycle_eval_{label}_w{workers}_v{views}"), n_cycle,
                         r.sec_per_eval);
            }
            println!("{:>6} {:>6} {:>6} {:>14.4} {:>14.4} {:>8.2}",
                     n_cycle, workers, views, times[0], times[1], times[0] / times[1]);
        }
    }

    // ---------------------------------------------------------------
    // 7. sharded serving: posterior prediction fanned over ranks
    // ---------------------------------------------------------------
    println!("\n== sharded serving: predict throughput (M=100, Q=1, D=3) ==");
    println!("{:>6} {:>8} {:>14} {:>14}", "Nt", "workers", "s/batch", "rows/s");
    {
        use gpparallel::collectives::Cluster;
        use gpparallel::coordinator::engine::serve::{worker_serve, DistributedPosterior};
        use gpparallel::coordinator::RustCpuBackend;
        use gpparallel::math::predict::PosteriorCore;
        use gpparallel::math::stats::sgpr_stats_fwd;

        let (n_fit, m, q, d) = (2048usize, 100usize, 1usize, 3usize);
        let spec = SyntheticSpec { n: n_fit, q, d, ..Default::default() };
        let dsf = generate_supervised(&spec, 9);
        let xf = dsf.x().unwrap();
        let zf = Mat::from_fn(m, q, |i, _| -2.0 + 4.0 * i as f64 / (m - 1) as f64);
        let kernf = RbfArd::iso(1.0, 1.0, q);
        let wf = vec![1.0; n_fit];
        let stf = sgpr_stats_fwd(&kernf, &xf, &wf, &dsf.y(), &zf);
        let core = PosteriorCore::new(kernf, zf, 50.0, &stf)?;

        let nt = if fast { 1024usize } else { 8192 };
        let serve_reps = if fast { 2 } else { 5 };
        let mut rngp = Rng64::new(10);
        let xstar = Mat::from_fn(nt, q, |_, _| rngp.uniform_range(-2.0, 2.0));
        for workers in [1usize, 2, 4] {
            let (core_ref, xs) = (&core, &xstar);
            let results = Cluster::run(workers, move |mut comm| {
                let mut backend = RustCpuBackend;
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 256,
                                                             &mut comm)
                        .expect("leader");
                    let mut mean = Mat::zeros(0, 0);
                    let mut var = Vec::new();
                    // warm the partition + scratch, then time steady state
                    dp.predict_into(&mut comm, &mut backend, xs, &mut mean, &mut var)
                        .expect("warmup");
                    let t0 = Instant::now();
                    for _ in 0..serve_reps {
                        dp.predict_into(&mut comm, &mut backend, xs, &mut mean,
                                        &mut var).expect("predict");
                    }
                    let per = t0.elapsed().as_secs_f64() / serve_reps as f64;
                    dp.finish(&mut comm).expect("finish");
                    per
                } else {
                    worker_serve(&mut comm, &mut backend).expect("serve");
                    0.0
                }
            });
            let t_serve = results[0];
            println!("{:>6} {:>8} {:>14.5} {:>14.0}",
                     nt, workers, t_serve, nt as f64 / t_serve);
            rec.push(&format!("serve_predict_w{workers}"), nt, t_serve);
        }

        // streamed pipeline over the same batch shape: batch k+1's
        // announcement + shard sends go out before batch k's gather, so
        // workers roll between batches without idling for the leader's
        // round-trip (`serve_stream_w{W}` vs `serve_predict_w{W}` is the
        // protocol-reordering win at equal compute)
        println!("\n== streamed serving: same batches through predict_stream ==");
        println!("{:>6} {:>8} {:>14} {:>14}", "Nt", "workers", "s/batch", "rows/s");
        let stream_batches: Vec<Mat> = (0..serve_reps).map(|_| xstar.clone()).collect();
        for workers in [1usize, 2, 4] {
            let (core_ref, bs) = (&core, &stream_batches);
            let results = Cluster::run(workers, move |mut comm| {
                let mut backend = RustCpuBackend;
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 256,
                                                              &mut comm)
                        .expect("leader");
                    let mut outs: Vec<(Mat, Vec<f64>)> =
                        bs.iter().map(|_| (Mat::zeros(0, 0), Vec::new())).collect();
                    // warm the partition + output buffers, then time the
                    // steady-state stream
                    dp.predict_stream_into(&mut comm, &mut backend, bs, &mut outs)
                        .expect("warmup");
                    let t0 = Instant::now();
                    dp.predict_stream_into(&mut comm, &mut backend, bs, &mut outs)
                        .expect("stream");
                    let per = t0.elapsed().as_secs_f64() / bs.len() as f64;
                    dp.finish(&mut comm).expect("finish");
                    per
                } else {
                    worker_serve(&mut comm, &mut backend).expect("serve");
                    0.0
                }
            });
            let t_stream = results[0];
            println!("{:>6} {:>8} {:>14.5} {:>14.0}",
                     nt, workers, t_stream, nt as f64 / t_stream);
            rec.push(&format!("serve_stream_w{workers}"), nt, t_stream);
        }
    }

    // ---------------------------------------------------------------
    // 8. stats-only pass + posterior hot-swap (the STATS verb):
    //    distributed posterior rebuild across ranks, and a full
    //    refit-and-swap round against an open serving session
    // ---------------------------------------------------------------
    println!("\n== stats-only pass + hot-swap + free stats (supervised, M=64, Q=1, D=2) ==");
    println!("{:>6} {:>8} {:>14} {:>14} {:>14}",
             "N", "workers", "stats s", "swap s", "free s");
    {
        use gpparallel::collectives::Cluster;
        use gpparallel::coordinator::{DistributedEvaluator, Partition};
        use gpparallel::models::SparseGpRegression;

        let n_stats = if fast { 1024usize } else { 4096 };
        let chunk = 256usize;
        let spec = SyntheticSpec { n: n_stats, q: 1, d: 2, ..Default::default() };
        let dss = generate_supervised(&spec, 12);
        let xs = dss.x().unwrap();
        let problem = SparseGpRegression::problem(&xs, &dss.y(), 64, "paper", 12);
        let x0 = problem.initial_params();
        let stats_reps = if fast { 2 } else { 5 };

        for workers in [1usize, 2, 4] {
            let part = Partition::new(n_stats, chunk, workers);
            let cfg = EngineConfig {
                workers,
                chunk,
                backend: BackendKind::RustCpu,
                artifacts_dir: "artifacts".into(),
                opt: OptChoice::Lbfgs(Lbfgs::default()),
                pipeline: true,
                verbose: false,
                simd: None,
            };
            let (p, x0_r) = (&problem, &x0);
            let results = Cluster::run(workers, move |comm| {
                let mut ev = DistributedEvaluator::new(p, &cfg, &part, comm)
                    .expect("evaluator");
                if ev.rank() == 0 {
                    // warm, then time the steady-state stats pass
                    let _ = ev.stats_pass(x0_r).expect("warmup");
                    let t0 = Instant::now();
                    for _ in 0..stats_reps {
                        std::hint::black_box(ev.stats_pass(x0_r).expect("stats"));
                    }
                    let t_stats = t0.elapsed().as_secs_f64() / stats_reps as f64;

                    // hot-swap: STATS round + core rebuild + rebroadcast
                    // against an open serving session
                    let core = ev.posterior_core_at(x0_r).expect("core");
                    ev.begin_serving(core, chunk).expect("serve");
                    let t0 = Instant::now();
                    for _ in 0..stats_reps {
                        ev.refit_and_swap(x0_r).expect("swap");
                    }
                    let t_swap = t0.elapsed().as_secs_f64() / stats_reps as f64;
                    ev.end_serving().expect("end");

                    // free end-of-run stats: after one evaluation at x0
                    // the posterior rebuild at the same parameters reuses
                    // the captured statistics — zero collective rounds,
                    // only the leader's M×M factorisations remain
                    let _ = ev.eval(x0_r).expect("eval");
                    let t0 = Instant::now();
                    for _ in 0..stats_reps {
                        std::hint::black_box(
                            ev.posterior_core_at(x0_r).expect("free stats"));
                    }
                    let t_free = t0.elapsed().as_secs_f64() / stats_reps as f64;
                    ev.finish();
                    Some((t_stats, t_swap, t_free))
                } else {
                    ev.serve().expect("worker");
                    None
                }
            });
            let (t_stats, t_swap, t_free) = results[0].expect("leader timing");
            println!("{:>6} {:>8} {:>14.5} {:>14.5} {:>14.5}",
                     n_stats, workers, t_stats, t_swap, t_free);
            rec.push(&format!("stats_pass_w{workers}"), n_stats, t_stats);
            if workers == 2 {
                rec.push("hot_swap", n_stats, t_swap);
                rec.push("free_stats", n_stats, t_free);
            }
        }
    }

    // ---------------------------------------------------------------
    // 9. SIMD dispatch tiers: the rewired microkernels at the scalar
    //    escape hatch vs the resolved default tier. The bench binary is
    //    its own process, so flipping the process-global level between
    //    timing loops is safe (no concurrent kernels).
    // ---------------------------------------------------------------
    {
        use gpparallel::linalg::simd::{self, SimdLevel};

        let default_level = simd::active();
        println!("\n== SIMD dispatch: off vs {} ==", default_level.name());
        println!("{:>8} {:>12} {:>12} {:>12} {:>12}",
                 "tier", "matmul ms", "syrk ms", "psi1 ms", "psi2 ms");
        let mm = if fast { 128usize } else { 256 };
        let mut rngs = Rng64::new(21);
        let a = Mat::from_fn(mm, mm, |_, _| rngs.normal());
        let b = Mat::from_fn(mm, mm, |_, _| rngs.normal());
        let (c_psi, m_psi, q_psi) = (if fast { 256usize } else { 1024 }, 100usize, 3usize);
        let mu = Mat::from_fn(c_psi, q_psi, |_, _| rngs.normal());
        let s = Mat::from_fn(c_psi, q_psi, |_, _| rngs.uniform_range(0.2, 1.2));
        let z = Mat::from_fn(m_psi, q_psi, |_, _| rngs.normal());
        let w = vec![1.0; c_psi];
        let kern = RbfArd::iso(1.0, 0.9, q_psi);
        let reps_mm = if fast { 4 } else { 8 };
        let reps_psi = if fast { 2 } else { 4 };

        // GPPAR_SIMD=off would make the two tiers identical; skip the
        // duplicate rather than emit two records under the same key
        let tiers: Vec<SimdLevel> = if default_level == SimdLevel::Off {
            vec![SimdLevel::Off]
        } else {
            vec![SimdLevel::Off, default_level]
        };
        for level in tiers {
            simd::set_active(level);
            let lv = level.name();
            let t_matmul = time_it(reps_mm, || a.matmul(&b));
            let t_syrk = time_it(reps_mm, || a.syrk());
            let t_psi1 = time_it(reps_psi, || kern.psi1(&mu, &s, &z));
            let t_psi2 = time_it(reps_psi, || kern.psi2(&mu, &s, &w, &z));
            println!("{:>8} {:>12.3} {:>12.3} {:>12.3} {:>12.3}",
                     lv, t_matmul * 1e3, t_syrk * 1e3, t_psi1 * 1e3, t_psi2 * 1e3);
            rec.push(&format!("simd_matmul_{lv}"), mm, t_matmul);
            rec.push(&format!("simd_syrk_{lv}"), mm, t_syrk);
            rec.push(&format!("simd_psi1_{lv}"), c_psi, t_psi1);
            rec.push(&format!("simd_psi2_{lv}"), c_psi, t_psi2);
        }
        simd::set_active(default_level);
    }

    // ---------------------------------------------------------------
    // 10. serving front-end under concurrent load: C closed-loop
    //     single-row clients through the micro-batching scheduler vs
    //     the same requests served sequentially (no coalescing). The
    //     scheduler's win is rows-per-cluster-round: at C=8 the
    //     deadline-coalesced batches amortise the leader round-trip
    //     across ~C rows.
    // ---------------------------------------------------------------
    println!("\n== serving front-end: closed-loop single-row clients (2 ranks) ==");
    println!("{:>8} {:>12} {:>12} {:>12} {:>8}",
             "clients", "p50 µs", "p99 µs", "rows/s", "fill");
    {
        use gpparallel::collectives::Cluster;
        use gpparallel::coordinator::engine::serve::{worker_serve, DistributedPosterior};
        use gpparallel::coordinator::{FrontendConfig, RustCpuBackend, ServingFrontend};
        use gpparallel::math::predict::PosteriorCore;
        use gpparallel::math::stats::sgpr_stats_fwd;
        use std::time::Duration;

        let (n_fit, m, q, d) = (1024usize, 64usize, 1usize, 2usize);
        let spec = SyntheticSpec { n: n_fit, q, d, ..Default::default() };
        let dsf = generate_supervised(&spec, 30);
        let xf = dsf.x().unwrap();
        let zf = Mat::from_fn(m, q, |i, _| -2.0 + 4.0 * i as f64 / (m - 1) as f64);
        let kernf = RbfArd::iso(1.0, 1.0, q);
        let wf = vec![1.0; n_fit];
        let stf = sgpr_stats_fwd(&kernf, &xf, &wf, &dsf.y(), &zf);
        let core = PosteriorCore::new(kernf, zf, 50.0, &stf)?;

        let k_req = if fast { 64usize } else { 256 };
        let nt = 512usize;
        let mut rngp = Rng64::new(31);
        let xstar = Mat::from_fn(nt, q, |_, _| rngp.uniform_range(-2.0, 2.0));

        // sequential baseline: the same single-row requests, one
        // cluster round each, no coalescing
        let (core_ref, xs_ref) = (&core, &xstar);
        let results = Cluster::run(2, move |mut comm| {
            let mut backend = RustCpuBackend;
            if comm.rank() == 0 {
                let mut dp = DistributedPosterior::leader(core_ref.clone(), 16,
                                                          &mut comm)
                    .expect("leader");
                let mut mean = Mat::zeros(0, 0);
                let mut var = Vec::new();
                let one = |row: usize| {
                    Mat::from_vec(1, q, xs_ref.as_slice()[row * q..(row + 1) * q].to_vec())
                };
                dp.predict_into(&mut comm, &mut backend, &one(0), &mut mean, &mut var)
                    .expect("warmup");
                let t0 = Instant::now();
                for i in 0..k_req {
                    dp.predict_into(&mut comm, &mut backend, &one(i % nt), &mut mean,
                                    &mut var).expect("predict");
                }
                let per = t0.elapsed().as_secs_f64() / k_req as f64;
                dp.finish(&mut comm).expect("finish");
                per
            } else {
                worker_serve(&mut comm, &mut backend).expect("serve");
                0.0
            }
        });
        let t_seq = results[0];
        println!("{:>8} {:>12.1} {:>12.1} {:>12.0} {:>8}",
                 "seq", t_seq * 1e6, t_seq * 1e6, 1.0 / t_seq, "-");
        rec.push("frontend_seq_1row", 1, t_seq);

        let mut rows_per_sec_c8 = 0.0;
        for clients in [1usize, 4, 8] {
            let (core_ref, xs_ref) = (&core, &xstar);
            let results = Cluster::run(2, move |mut comm| {
                let mut backend = RustCpuBackend;
                if comm.rank() == 0 {
                    let mut dp = DistributedPosterior::leader(core_ref.clone(), 16,
                                                              &mut comm)
                        .expect("leader");
                    let fe = ServingFrontend::new(FrontendConfig {
                        max_batch_rows: 32,
                        max_wait: Duration::from_micros(50),
                        queue_rows: 1024,
                        dump_every: None,
                    }, q, d);
                    let t0 = Instant::now();
                    let (report, mut lats) = std::thread::scope(|s| {
                        let handle = fe.handle();
                        let client_joins: Vec<_> = (0..clients).map(|c| {
                            let h = handle.clone();
                            s.spawn(move || {
                                let mut lats = Vec::with_capacity(k_req);
                                for i in 0..k_req {
                                    let row = (c * k_req + i) % nt;
                                    let xrow = Mat::from_vec(
                                        1, q,
                                        xs_ref.as_slice()[row * q..(row + 1) * q].to_vec());
                                    let t = Instant::now();
                                    h.predict(xrow).expect("predict");
                                    lats.push(t.elapsed().as_secs_f64());
                                }
                                lats
                            })
                        }).collect();
                        // closer: when every client is done, close the
                        // queue so the scheduler below drains and returns
                        let closer = s.spawn(move || {
                            let mut all = Vec::new();
                            for j in client_joins {
                                all.extend(j.join().expect("client thread"));
                            }
                            handle.close();
                            all
                        });
                        let report = fe.run(&mut dp, &mut comm, &mut backend);
                        (report, closer.join().expect("closer thread"))
                    });
                    let wall = t0.elapsed().as_secs_f64();
                    dp.finish(&mut comm).expect("finish");
                    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    let p50 = lats[lats.len() / 2];
                    let p99 = lats[(lats.len() * 99 / 100).min(lats.len() - 1)];
                    let rps = (clients * k_req) as f64 / wall;
                    Some((p50, p99, rps, report.snapshot.batch_fill))
                } else {
                    worker_serve(&mut comm, &mut backend).expect("serve");
                    None
                }
            });
            let (p50, p99, rps, fill) = results[0].expect("leader timing");
            println!("{:>8} {:>12.1} {:>12.1} {:>12.0} {:>8.3}",
                     clients, p50 * 1e6, p99 * 1e6, rps, fill);
            rec.push(&format!("frontend_load_c{clients}_p50"), clients, p50);
            rec.push(&format!("frontend_load_c{clients}_p99"), clients, p99);
            rec.push(&format!("frontend_load_c{clients}_row"), clients * k_req, 1.0 / rps);
            if clients == 8 {
                rows_per_sec_c8 = rps;
            }
        }
        println!("  c=8 throughput vs sequential: {:.2}x (micro-batching amortises the \
                  per-round leader round-trip)",
                 rows_per_sec_c8 * t_seq);
    }

    // ---------------------------------------------------------------
    // 11. transport abstraction overhead: a 2-rank ping-pong round
    //     trip through `Comm` over `InMemoryTransport` — the dynamic
    //     dispatch + Result plumbing the Transport trait put on every
    //     point-to-point hop, tracked so the refactor's cost stays in
    //     the noise against the protocol's compute rounds.
    // ---------------------------------------------------------------
    println!("\n== comm transport overhead: 2-rank ping-pong (send + recv) ==");
    println!("{:>8} {:>14}", "elems", "µs/round-trip");
    {
        use gpparallel::collectives::protocol::TAG_BENCH_PINGPONG;
        use gpparallel::collectives::Cluster;

        let rounds = if fast { 2_000usize } else { 20_000 };
        for payload in [8usize, 1024] {
            let results = Cluster::run(2, move |mut comm| {
                let data = vec![1.0f64; payload];
                if comm.rank() == 0 {
                    // warm the channel + parked-queue paths
                    comm.send(1, TAG_BENCH_PINGPONG, &data).expect("send");
                    std::hint::black_box(comm.recv(1, TAG_BENCH_PINGPONG).expect("recv"));
                    let t0 = Instant::now();
                    for _ in 0..rounds {
                        comm.send(1, TAG_BENCH_PINGPONG, &data).expect("send");
                        std::hint::black_box(comm.recv(1, TAG_BENCH_PINGPONG).expect("recv"));
                    }
                    t0.elapsed().as_secs_f64() / rounds as f64
                } else {
                    for _ in 0..rounds + 1 {
                        let msg = comm.recv(0, TAG_BENCH_PINGPONG).expect("recv");
                        comm.send(0, TAG_BENCH_PINGPONG, &msg).expect("send");
                    }
                    0.0
                }
            });
            let t_rt = results[0];
            println!("{:>8} {:>14.3}", payload, t_rt * 1e6);
            rec.push("comm_transport_overhead", payload, t_rt);
        }
    }

    // ---------------------------------------------------------------
    // 12. out-of-core chunk store: steady-state sequential read
    //     throughput (resident vs on-disk, same bytes, same grid) and
    //     the streamed distributed cycle — the O(chunk)-working-set
    //     evaluation path — at 1 and 4 ranks.
    // ---------------------------------------------------------------
    println!("\n== chunk store: chunked reads + streamed SGPR cycle ==");
    {
        use gpparallel::data::store::{materialize, ChunkReader as _, ChunkSource,
                                      FileStore, ResidentStore};
        use gpparallel::data::synthetic::generate_supervised_to_store;
        use gpparallel::models::SparseGpRegression;
        use std::sync::Arc;

        let n_store = if fast { 4096usize } else { 16384 };
        let chunk_rows = 512usize;
        let spec = SyntheticSpec { n: n_store, q: 1, d: 3, ..Default::default() };
        let dir = std::env::temp_dir().join(format!("gpparallel_micro_store_{}",
                                                    std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_supervised_to_store(&spec, 40, &dir, chunk_rows)?;
        let file: Arc<dyn ChunkSource> = Arc::new(FileStore::open(&dir)?);
        let (x_res, y_res) = materialize(file.as_ref())?;
        let resident: Arc<dyn ChunkSource> =
            Arc::new(ResidentStore::from_mats(x_res, y_res, chunk_rows)?);

        let read_reps = if fast { 2 } else { 5 };
        for (name, src) in [("resident", &resident), ("file", &file)] {
            let man = src.manifest();
            let chunks = man.num_chunks();
            let mut reader = src.open_reader()?;
            let mut xbuf = vec![0.0; chunk_rows * man.q];
            let mut ybuf = vec![0.0; chunk_rows * man.d];
            // warm (page cache + reader scratch), then time full passes
            for k in 0..chunks {
                reader.read_chunk(k, &mut xbuf, &mut ybuf)?;
            }
            let t = time_it(read_reps, || {
                for k in 0..chunks {
                    reader.read_chunk(k, &mut xbuf, &mut ybuf).expect("read chunk");
                }
                std::hint::black_box(ybuf[0])
            });
            println!("  chunked_read_{name:<9}: {:>9.3} ms/pass  ({chunks} chunks of {chunk_rows})",
                     t * 1e3);
            rec.push(&format!("chunked_read_{name}"), n_store, t);
        }

        for workers in [1usize, 4] {
            let problem = SparseGpRegression::problem_from_store(&file, 64, "paper", 41)?;
            let cfg = EngineConfig {
                workers,
                chunk: chunk_rows,
                backend: BackendKind::RustCpu,
                artifacts_dir: "artifacts".into(),
                opt: OptChoice::Lbfgs(Lbfgs::default()),
                pipeline: true,
                verbose: false,
                simd: None,
            };
            let r = Engine::new(problem, cfg)?.time_iterations(1)?;
            println!("  cycle_eval_chunked_w{workers}: {:>9.4} s/iter  (N={n_store}, streamed from disk)",
                     r.sec_per_eval);
            rec.push(&format!("cycle_eval_chunked_w{workers}"), n_store, r.sec_per_eval);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    rec.write("BENCH_micro.json")?;
    println!("\nwrote BENCH_micro.json ({} records)", rec.0.len());
    Ok(())
}
