//! Micro + ablation benches (the design-choice studies DESIGN.md lists):
//!
//!   1. psi-statistics kernel: Rust scalar loops vs the XLA artifact,
//!      per chunk (the per-device building block behind Fig 1a).
//!   2. chunk-size ablation at fixed N (padding/dispatch overhead trade).
//!   3. sparse-distributed vs dense O(N³) GP crossover.
//!   4. optimiser ablation: L-BFGS vs SCG vs Adam on the same model.
//!
//!   cargo bench --bench micro      (MICRO_FAST=1 for the short version)

use gpparallel::baselines::DenseGp;
use gpparallel::config::BackendKind;
use gpparallel::coordinator::backend::{Backend, ChunkData, RustCpuBackend, ViewParams,
                                       XlaBackend};
use gpparallel::coordinator::{Engine, EngineConfig, OptChoice};
use gpparallel::data::rng::Rng64;
use gpparallel::data::synthetic::{generate, generate_supervised, SyntheticSpec};
use gpparallel::kern::RbfArd;
use gpparallel::linalg::Mat;
use gpparallel::models::BayesianGplvm;
use gpparallel::optim::{Adam, Lbfgs, Scg};
use std::time::Instant;

fn time_it<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("MICRO_FAST").is_ok();

    // ---------------------------------------------------------------
    // 1. per-chunk stats: Rust vs XLA (the paper's Table-1 kernel)
    // ---------------------------------------------------------------
    println!("== per-chunk psi statistics (C=1024, M=100, Q=1, D=3) ==");
    let (c, m, q, d) = (1024usize, 100usize, 1usize, 3usize);
    let mut rng = Rng64::new(1);
    let mu = Mat::from_fn(c, q, |_, _| rng.normal());
    let s = Mat::from_fn(c, q, |_, _| rng.uniform_range(0.2, 1.2));
    let y = Mat::from_fn(c, d, |_, _| rng.normal());
    let z = Mat::from_fn(m, q, |_, _| rng.normal());
    let kern = RbfArd::iso(1.0, 1.0, q);
    let log_hyp = kern.to_log_hyp();
    let chunk = ChunkData { start: 0, live: c, y, x: Mat::zeros(0, 0), w: vec![1.0; c] };
    let vp = ViewParams { z: &z, log_hyp: &log_hyp };

    let reps = if fast { 3 } else { 8 };
    let mut cpu = RustCpuBackend;
    let t_cpu_fwd = time_it(reps, || cpu.stats_fwd(&chunk, Some((&mu, &s)), &vp, true).unwrap());
    println!("  rust-cpu  stats_fwd : {:>9.2} ms", t_cpu_fwd * 1e3);

    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    if have_artifacts {
        let (rt, mut xla) = XlaBackend::from_dir(std::path::Path::new("artifacts"), "paper")?;
        let _ = &rt;
        let t_xla_fwd = time_it(reps, || xla.stats_fwd(&chunk, Some((&mu, &s)), &vp, true).unwrap());
        println!("  xla       stats_fwd : {:>9.2} ms   ({:.2}x vs rust-cpu)",
                 t_xla_fwd * 1e3, t_cpu_fwd / t_xla_fwd);

        use gpparallel::math::stats::StatsCts;
        let cts = StatsCts {
            c_psi0: 0.3,
            c_p: Mat::from_fn(m, d, |_, _| 0.01),
            c_psi2: Mat::from_fn(m, m, |_, _| 0.001),
            c_tryy: -0.5,
            c_kl: -1.0,
        };
        let t_cpu_vjp = time_it(reps, || cpu.stats_vjp(&chunk, Some((&mu, &s)), &vp, &cts).unwrap());
        let t_xla_vjp = time_it(reps, || xla.stats_vjp(&chunk, Some((&mu, &s)), &vp, &cts).unwrap());
        println!("  rust-cpu  stats_vjp : {:>9.2} ms", t_cpu_vjp * 1e3);
        println!("  xla       stats_vjp : {:>9.2} ms   ({:.2}x vs rust-cpu)",
                 t_xla_vjp * 1e3, t_cpu_vjp / t_xla_vjp);
    } else {
        println!("  (artifacts missing; run `make artifacts` for the XLA rows)");
    }

    // ---------------------------------------------------------------
    // 2. chunk-size ablation (fixed N, XLA needs matching config so we
    //    ablate the Rust backend where chunk is free)
    // ---------------------------------------------------------------
    println!("\n== chunk-size ablation (rust-cpu, N=4096, 2 workers) ==");
    let spec = SyntheticSpec { n: 4096, q: 1, d: 3, ..Default::default() };
    let ds = generate(&spec, 0);
    for chunk_size in [256usize, 512, 1024, 2048, 4096] {
        let problem = BayesianGplvm::problem(&ds.y, 1, 100, "paper", 0);
        let cfg = EngineConfig {
            workers: 2,
            chunk: chunk_size,
            backend: BackendKind::RustCpu,
            artifacts_dir: "artifacts".into(),
            opt: OptChoice::Lbfgs(Lbfgs::default()),
            verbose: false,
        };
        let r = Engine::new(problem, cfg)?.time_iterations(1)?;
        println!("  chunk {:>5}: {:>8.3} s/iter", chunk_size, r.sec_per_eval);
    }

    // ---------------------------------------------------------------
    // 3. sparse-distributed vs dense O(N^3) crossover
    // ---------------------------------------------------------------
    println!("\n== sparse (M=16) vs dense GP: one hyperparameter-objective eval ==");
    println!("{:>6} {:>14} {:>14} {:>8}", "N", "sparse s", "dense s", "ratio");
    let sizes = if fast { vec![256, 512] } else { vec![256, 512, 1024, 2048] };
    for n in sizes {
        let spec = SyntheticSpec { n, q: 1, d: 1, ..Default::default() };
        let dsn = generate_supervised(&spec, 3);
        let x = dsn.x.clone().unwrap();
        let kern = RbfArd::iso(1.0, 1.0, 1);

        // sparse: one full distributed objective evaluation
        let problem = gpparallel::coordinator::Problem {
            latent: gpparallel::coordinator::LatentSpec::Observed(x.clone()),
            views: vec![gpparallel::coordinator::ViewSpec {
                y: dsn.y.clone(),
                z0: Mat::from_fn(16, 1, |i, _| -2.0 + 4.0 * i as f64 / 15.0),
                kern0: kern.clone(),
                beta0: 10.0,
                aot_config: "quickstart".into(),
            }],
            q: 1,
        };
        let cfg = EngineConfig {
            workers: 1,
            chunk: 256,
            backend: BackendKind::RustCpu,
            artifacts_dir: "artifacts".into(),
            opt: OptChoice::Lbfgs(Lbfgs::default()),
            verbose: false,
        };
        let t_sparse = Engine::new(problem, cfg)?.time_iterations(1)?.sec_per_eval;

        // dense: one exact-marginal-likelihood-with-gradients evaluation
        let t_dense = time_it(1, || DenseGp::lml_and_grads(&kern, 10.0f64.ln(), &x, &dsn.y).unwrap());
        println!("{:>6} {:>14.4} {:>14.4} {:>8.2}", n, t_sparse, t_dense,
                 t_dense / t_sparse);
    }

    // ---------------------------------------------------------------
    // 4. optimiser ablation
    // ---------------------------------------------------------------
    println!("\n== optimiser ablation (BGP-LVM, N=256, 40-iteration budget) ==");
    let spec = SyntheticSpec { n: 256, q: 2, d: 3, ..Default::default() };
    let ds = generate(&spec, 4);
    for (name, opt) in [
        ("L-BFGS", OptChoice::Lbfgs(Lbfgs { max_iters: 40, ..Default::default() })),
        ("SCG", OptChoice::Scg(Scg { max_iters: 40, ..Default::default() })),
        ("Adam", OptChoice::Adam(Adam { lr: 5e-2, max_iters: 40, ..Default::default() })),
    ] {
        let problem = BayesianGplvm::problem(&ds.y, 2, 16, "test", 4);
        let cfg = EngineConfig {
            workers: 1,
            chunk: 64,
            backend: BackendKind::RustCpu,
            artifacts_dir: "artifacts".into(),
            opt,
            verbose: false,
        };
        let r = Engine::new(problem, cfg)?.train()?;
        println!("  {:>7}: bound {:>10.2} -> {:>10.2}  ({} evals)",
                 name, r.trace.first().unwrap(), r.trace.last().unwrap(),
                 r.evaluations);
    }

    Ok(())
}
