//! Fig 1b reproduction: percentage of per-iteration time spent in the
//! indistributable computation, vs dataset size.
//!
//!   cargo bench --bench fig1b_indistributable
//!   FIG1B_FAST=1 cargo bench --bench fig1b_indistributable
//!
//! The paper's claim: the indistributable share (the M×M core +
//! collectives at the leader) is small and shrinks as N grows, so more
//! compute keeps helping. We measure the same split with the coordinator's
//! phase timers for both backends, and emit results/fig1b.csv.

use gpparallel::config::BackendKind;
use gpparallel::coordinator::{Engine, EngineConfig, OptChoice};
use gpparallel::data::synthetic::{generate, SyntheticSpec};
use gpparallel::metrics::Phase;
use gpparallel::models::BayesianGplvm;
use gpparallel::optim::Lbfgs;
use std::fmt::Write as _;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("FIG1B_FAST").is_ok();
    // default sweep tops out at 16k so `cargo bench` stays ~minutes on
    // this single-core host; FIG1B_HUGE=1 extends to the paper's full 64k.
    let huge = std::env::var("FIG1B_HUGE").is_ok();
    let sizes: Vec<usize> = if fast {
        vec![1024, 2048, 4096]
    } else if huge {
        vec![1024, 2048, 4096, 8192, 16384, 32768, 65536]
    } else {
        vec![1024, 2048, 4096, 8192, 16384]
    };
    let evals = 2;

    println!("Fig 1b — indistributable share of iteration time (M=100, Q=1, D=3)");
    println!("{:>9} {:>8} {:>10} {:>12} {:>12}",
             "backend", "N", "indist %", "core ms", "total ms");

    let mut rows = Vec::new();
    for backend in [BackendKind::RustCpu, BackendKind::Xla] {
        for &n in &sizes {
            let spec = SyntheticSpec { n, q: 1, d: 3, ..Default::default() };
            let ds = generate(&spec, 0);
            let problem = BayesianGplvm::problem(&ds.y(), 1, 100, "paper", 0);
            let cfg = EngineConfig {
                workers: 2,
                chunk: 1024,
                backend,
                artifacts_dir: "artifacts".into(),
                opt: OptChoice::Lbfgs(Lbfgs::default()),
                pipeline: true,
                verbose: false,
                simd: None,
            };
            let engine = Engine::new(problem, cfg)?;
            let r = engine.time_iterations(evals)?;
            let frac = r.timing.indistributable_fraction();
            let core_ms = r.timing.get(Phase::BoundCore).as_secs_f64() * 1e3
                / evals as f64;
            let total_ms = r.timing.total().as_secs_f64() * 1e3 / evals as f64;
            println!("{:>9} {:>8} {:>10.2} {:>12.2} {:>12.1}",
                     backend.name(), n, frac * 100.0, core_ms, total_ms);
            rows.push((backend, n, frac, core_ms, total_ms));
        }
        // paper claim: share decreases with N
        let fracs: Vec<f64> = rows.iter()
            .filter(|r| r.0 == backend)
            .map(|r| r.2)
            .collect();
        if fracs.len() >= 2 {
            let dir = if fracs.last().unwrap() < fracs.first().unwrap() {
                "decreases"
            } else {
                "does NOT decrease"
            };
            println!("  -> {} share {dir} with N ({:.2}% at N={} vs {:.2}% at N={})",
                     backend.name(), fracs.first().unwrap() * 100.0, sizes[0],
                     fracs.last().unwrap() * 100.0, sizes[sizes.len() - 1]);
        }
    }

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("backend,n,indist_frac,core_ms_per_iter,total_ms_per_iter\n");
    for (b, n, f, c, t) in &rows {
        let _ = writeln!(csv, "{},{},{},{},{}", b.name(), n, f, c, t);
    }
    std::fs::write("results/fig1b.csv", csv)?;
    println!("\nwrote results/fig1b.csv");
    Ok(())
}
